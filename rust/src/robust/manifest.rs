//! The per-run manifest: durable, atomically-rewritten progress state for
//! checkpointed sweep execution.
//!
//! A manifest records everything a resumed process needs: the run kind,
//! the full grid JSON (so `--resume <manifest>` needs no `--grid`), the
//! launch options, a content hash binding the manifest to exactly that
//! grid + byte-relevant options, the summary header, and one entry per
//! cell. `done` cells carry their summary row **verbatim** plus the sizes
//! of their export files; `failed` cells carry the cumulative attempt
//! count and the last failure reason; everything else is `pending`.
//!
//! Resume correctness rests on two properties: cells are pure functions
//! of `(spec, seed)` (re-running a non-`done` cell reproduces exactly the
//! bytes the crashed run would have written), and `done` rows are
//! replayed from the manifest rather than recomputed — so the assembled
//! summary is byte-identical to an uninterrupted run by construction.
//! [`RunManifest::reconcile_exports`] closes the remaining gap: a `done`
//! cell whose export files are missing or mis-sized (the crash landed
//! between the cell's exports and the manifest rewrite never happens —
//! the manifest is written *after* the exports — but a user may delete
//! files) is demoted back to `pending` and re-run.

use super::fsx;
use crate::util::json::{self, Json};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Schema version, bumped on incompatible manifest changes.
pub const MANIFEST_VERSION: usize = 1;

/// 64-bit FNV-1a — dependency-free, stable across platforms and runs.
/// Lives in the core [`crate::shard`] module (shard ownership hashes the
/// same bytes); re-exported here for the manifest's historical callers.
pub use crate::shard::fnv1a64;

/// The identity hash binding a manifest to one `(kind, grid, options)`
/// triple. `identity` holds only the options that change output bytes
/// (dt, ramp interval, export scales) — worker counts, batch widths, and
/// window sizes are byte-invariant by contract and deliberately excluded,
/// so a sweep can resume with a different parallel layout.
pub fn content_hash(kind: &str, grid: &Json, identity: &Json) -> String {
    let canonical = json::to_string(&json::obj([
        ("kind", Json::Str(kind.to_string())),
        ("grid", grid.clone()),
        ("identity", identity.clone()),
    ]));
    format!("fnv1a:{:016x}", fnv1a64(canonical.as_bytes()))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellStatus {
    Pending,
    Done,
    Failed,
}

impl CellStatus {
    fn as_str(self) -> &'static str {
        match self {
            CellStatus::Pending => "pending",
            CellStatus::Done => "done",
            CellStatus::Failed => "failed",
        }
    }

    fn from_str(s: &str) -> Result<CellStatus> {
        Ok(match s {
            "pending" => CellStatus::Pending,
            "done" => CellStatus::Done,
            "failed" => CellStatus::Failed,
            other => bail!("unknown cell status '{other}'"),
        })
    }
}

/// One export file a `done` cell wrote, path relative to the run
/// directory; the recorded size lets resume detect deleted or truncated
/// artifacts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExportRecord {
    pub path: String,
    pub bytes: u64,
}

/// One cell's durable state.
#[derive(Debug, Clone)]
pub struct CellState {
    pub status: CellStatus,
    /// Cumulative attempts across every run of this manifest.
    pub attempts: u32,
    /// The summary row (with trailing newline), recorded verbatim at
    /// completion and replayed verbatim on resume.
    pub row: Option<String>,
    /// Last failure reason (`failed` cells).
    pub reason: Option<String>,
    pub exports: Vec<ExportRecord>,
}

impl CellState {
    fn pending() -> CellState {
        CellState {
            status: CellStatus::Pending,
            attempts: 0,
            row: None,
            reason: None,
            exports: Vec::new(),
        }
    }
}

/// The durable run manifest. See the module docs for the schema contract.
#[derive(Debug, Clone)]
pub struct RunManifest {
    /// `"sweep"` or `"site_sweep"`.
    pub kind: String,
    /// Grid name (reporting only).
    pub name: String,
    /// [`content_hash`] of `(kind, grid, identity-options)`.
    pub grid_hash: String,
    /// The full grid JSON — resume reloads the grid from here.
    pub grid: Json,
    /// The options the run was launched with (resume CLI defaults).
    pub options: Json,
    /// The summary header line(s), recorded once at first completion.
    pub header: Option<String>,
    pub cells: BTreeMap<String, CellState>,
}

impl RunManifest {
    /// A fresh all-`pending` manifest over the expanded cell ids.
    pub fn new(
        kind: &str,
        name: &str,
        grid_hash: String,
        grid: Json,
        options: Json,
        ids: &[String],
    ) -> RunManifest {
        RunManifest {
            kind: kind.to_string(),
            name: name.to_string(),
            grid_hash,
            grid,
            options,
            header: None,
            cells: ids.iter().map(|id| (id.clone(), CellState::pending())).collect(),
        }
    }

    /// Refuse to resume against the wrong grid/options/cell set — the
    /// summary a mismatched resume would assemble could never equal the
    /// uninterrupted run's.
    pub fn ensure_matches(&self, kind: &str, grid_hash: &str, ids: &[String]) -> Result<()> {
        ensure!(self.kind == kind, "manifest is a '{}' run, not a '{kind}' run", self.kind);
        ensure!(
            self.grid_hash == grid_hash,
            "manifest hash {} does not match this grid + options ({grid_hash}): \
             the manifest was created from a different grid or with different \
             dt/ramp/scale options",
            self.grid_hash
        );
        ensure!(
            self.cells.len() == ids.len() && ids.iter().all(|id| self.cells.contains_key(id)),
            "manifest cell set does not match the grid expansion ({} vs {} cells)",
            self.cells.len(),
            ids.len()
        );
        Ok(())
    }

    /// Demote `done` cells whose recorded exports are missing or mis-sized
    /// under `root` back to `pending` (they re-run on resume). Returns the
    /// number of demoted cells.
    pub fn reconcile_exports(&mut self, root: &Path) -> usize {
        let mut demoted = 0;
        for state in self.cells.values_mut() {
            if state.status != CellStatus::Done {
                continue;
            }
            let intact = state.row.is_some()
                && state.exports.iter().all(|e| {
                    std::fs::metadata(root.join(&e.path))
                        .map(|m| m.len() == e.bytes)
                        .unwrap_or(false)
                });
            if !intact {
                let attempts = state.attempts;
                *state = CellState::pending();
                state.attempts = attempts;
                demoted += 1;
            }
        }
        demoted
    }

    pub fn is_done(&self, id: &str) -> bool {
        self.cells.get(id).map(|c| c.status == CellStatus::Done).unwrap_or(false)
    }

    /// Cumulative attempts recorded for `id` (0 for unknown cells).
    pub fn attempts(&self, id: &str) -> u32 {
        self.cells.get(id).map(|c| c.attempts).unwrap_or(0)
    }

    /// The recorded summary row of a `done` cell.
    pub fn row(&self, id: &str) -> Option<&str> {
        self.cells.get(id).and_then(|c| match c.status {
            CellStatus::Done => c.row.as_deref(),
            _ => None,
        })
    }

    pub fn done_count(&self) -> usize {
        self.cells.values().filter(|c| c.status == CellStatus::Done).count()
    }

    pub fn mark_done(&mut self, id: &str, attempts: u32, row: String, exports: Vec<ExportRecord>) {
        if let Some(c) = self.cells.get_mut(id) {
            *c = CellState {
                status: CellStatus::Done,
                attempts,
                row: Some(row),
                reason: None,
                exports,
            };
        }
    }

    pub fn mark_failed(&mut self, id: &str, attempts: u32, reason: String) {
        if let Some(c) = self.cells.get_mut(id) {
            *c = CellState {
                status: CellStatus::Failed,
                attempts,
                row: None,
                reason: Some(reason),
                exports: Vec::new(),
            };
        }
    }

    pub fn to_json(&self) -> Json {
        let cells: BTreeMap<String, Json> = self
            .cells
            .iter()
            .map(|(id, c)| {
                let mut fields = vec![
                    ("status", Json::Str(c.status.as_str().to_string())),
                    ("attempts", Json::Num(c.attempts as f64)),
                ];
                if let Some(row) = &c.row {
                    fields.push(("row", Json::Str(row.clone())));
                }
                if let Some(reason) = &c.reason {
                    fields.push(("reason", Json::Str(reason.clone())));
                }
                if !c.exports.is_empty() {
                    fields.push((
                        "exports",
                        Json::Arr(
                            c.exports
                                .iter()
                                .map(|e| {
                                    json::obj([
                                        ("path", Json::Str(e.path.clone())),
                                        ("bytes", Json::Num(e.bytes as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                (id.clone(), json::obj(fields))
            })
            .collect();
        let mut fields = vec![
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("kind", Json::Str(self.kind.clone())),
            ("name", Json::Str(self.name.clone())),
            ("grid_hash", Json::Str(self.grid_hash.clone())),
            ("grid", self.grid.clone()),
            ("options", self.options.clone()),
        ];
        if let Some(h) = &self.header {
            fields.push(("header", Json::Str(h.clone())));
        }
        fields.push(("cells", Json::Obj(cells)));
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<RunManifest> {
        let version = v.usize_field("version").map_err(anyhow::Error::from)?;
        ensure!(
            version == MANIFEST_VERSION,
            "manifest version {version} unsupported (expected {MANIFEST_VERSION})"
        );
        let mut cells = BTreeMap::new();
        let Json::Obj(raw) = v.get("cells").map_err(anyhow::Error::from)? else {
            bail!("manifest 'cells' must be an object");
        };
        for (id, c) in raw {
            let status =
                CellStatus::from_str(&c.str_field("status").map_err(anyhow::Error::from)?)
                    .with_context(|| format!("cell '{id}'"))?;
            let attempts = match c.get_opt("attempts") {
                Some(a) => a.as_usize().map_err(anyhow::Error::from)? as u32,
                None => 0,
            };
            let row = match c.get_opt("row") {
                Some(r) => Some(r.as_str().map_err(anyhow::Error::from)?.to_string()),
                None => None,
            };
            let reason = match c.get_opt("reason") {
                Some(r) => Some(r.as_str().map_err(anyhow::Error::from)?.to_string()),
                None => None,
            };
            let mut exports = Vec::new();
            if let Some(arr) = c.get_opt("exports") {
                for e in arr.as_arr().map_err(anyhow::Error::from)? {
                    exports.push(ExportRecord {
                        path: e.str_field("path").map_err(anyhow::Error::from)?,
                        bytes: e.f64_field("bytes").map_err(anyhow::Error::from)? as u64,
                    });
                }
            }
            if status == CellStatus::Done {
                ensure!(row.is_some(), "done cell '{id}' is missing its summary row");
            }
            cells.insert(id.clone(), CellState { status, attempts, row, reason, exports });
        }
        Ok(RunManifest {
            kind: v.str_field("kind").map_err(anyhow::Error::from)?,
            name: v.str_field("name").map_err(anyhow::Error::from)?,
            grid_hash: v.str_field("grid_hash").map_err(anyhow::Error::from)?,
            grid: v.get("grid").map_err(anyhow::Error::from)?.clone(),
            options: v.get("options").map_err(anyhow::Error::from)?.clone(),
            header: match v.get_opt("header") {
                Some(h) => Some(h.as_str().map_err(anyhow::Error::from)?.to_string()),
                None => None,
            },
            cells,
        })
    }

    pub fn load(path: &Path) -> Result<RunManifest> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing manifest {}", path.display()))
    }

    /// Atomic save: pretty JSON staged to `<path>.tmp`, renamed into place
    /// ([`json::write_file`] carries the temp-and-rename contract).
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json())
            .with_context(|| format!("saving manifest {}", path.display()))
    }
}

/// Thread-safe manifest ownership for a running checkpointed sweep: every
/// mutation rewrites the manifest on disk before the cell's worker moves
/// on, so the durable state always covers every completed cell.
pub struct ManifestKeeper {
    inner: Mutex<RunManifest>,
    path: PathBuf,
}

impl ManifestKeeper {
    /// Take ownership and persist the initial state immediately — a crash
    /// at any later point finds a loadable manifest on disk.
    pub fn new(manifest: RunManifest, path: PathBuf) -> Result<ManifestKeeper> {
        manifest.save(&path)?;
        Ok(ManifestKeeper { inner: Mutex::new(manifest), path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read-only access (no disk write).
    pub fn with<R>(&self, f: impl FnOnce(&RunManifest) -> R) -> R {
        f(&self.lock())
    }

    /// Mutate and atomically persist.
    pub fn update<R>(&self, f: impl FnOnce(&mut RunManifest) -> R) -> Result<R> {
        let mut m = self.lock();
        let r = f(&mut m);
        m.save(&self.path)?;
        Ok(r)
    }

    /// The final state (the lock is gone once the pool has joined).
    pub fn into_inner(self) -> RunManifest {
        self.inner.into_inner().unwrap_or_else(|e| e.into_inner())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RunManifest> {
        // A worker panicking inside `f` is already caught upstream; don't
        // let a poisoned mutex cascade into every later cell.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(ids: &[&str]) -> RunManifest {
        let grid = json::obj([("name", Json::Str("g".into()))]);
        let identity = json::obj([("dt_s", Json::Num(0.25))]);
        let hash = content_hash("sweep", &grid, &identity);
        let ids: Vec<String> = ids.iter().map(|s| s.to_string()).collect();
        RunManifest::new("sweep", "g", hash, grid, identity, &ids)
    }

    #[test]
    fn hash_is_stable_and_sensitive() {
        let grid = json::obj([("name", Json::Str("g".into()))]);
        let identity = json::obj([("dt_s", Json::Num(0.25))]);
        let h1 = content_hash("sweep", &grid, &identity);
        let h2 = content_hash("sweep", &grid, &identity);
        assert_eq!(h1, h2);
        assert!(h1.starts_with("fnv1a:"));
        let other = json::obj([("dt_s", Json::Num(0.5))]);
        assert_ne!(h1, content_hash("sweep", &grid, &other));
        assert_ne!(h1, content_hash("site_sweep", &grid, &identity));
    }

    #[test]
    fn roundtrips_through_json() {
        let mut m = sample(&["a", "b", "c"]);
        m.header = Some("cell,peak_w\n".into());
        m.mark_done(
            "a",
            2,
            "a,1.5\n".into(),
            vec![ExportRecord { path: "a/racks_1s.csv".into(), bytes: 128 }],
        );
        m.mark_failed("b", 3, "panicked: boom".into());
        let back = RunManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.kind, "sweep");
        assert_eq!(back.grid_hash, m.grid_hash);
        assert_eq!(back.header.as_deref(), Some("cell,peak_w\n"));
        assert!(back.is_done("a") && !back.is_done("b") && !back.is_done("c"));
        assert_eq!(back.row("a"), Some("a,1.5\n"));
        assert_eq!(back.row("b"), None);
        assert_eq!(back.attempts("b"), 3);
        assert_eq!(back.cells["b"].reason.as_deref(), Some("panicked: boom"));
        assert_eq!(back.cells["a"].exports, m.cells["a"].exports);
        assert_eq!(back.done_count(), 1);
    }

    #[test]
    fn ensure_matches_rejects_mismatches() {
        let m = sample(&["a", "b"]);
        let ids: Vec<String> = vec!["a".into(), "b".into()];
        m.ensure_matches("sweep", &m.grid_hash, &ids).unwrap();
        assert!(m.ensure_matches("site_sweep", &m.grid_hash, &ids).is_err());
        assert!(m.ensure_matches("sweep", "fnv1a:0000000000000000", &ids).is_err());
        let wrong: Vec<String> = vec!["a".into(), "z".into()];
        assert!(m.ensure_matches("sweep", &m.grid_hash, &wrong).is_err());
    }

    #[test]
    fn reconcile_demotes_missing_and_mis_sized_exports() {
        let root = std::env::temp_dir().join("powertrace_test_manifest_reconcile");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("a")).unwrap();
        std::fs::write(root.join("a/out.csv"), b"12345").unwrap();
        let mut m = sample(&["a", "b"]);
        let rec = |p: &str| vec![ExportRecord { path: p.to_string(), bytes: 5 }];
        m.mark_done("a", 1, "row-a\n".into(), rec("a/out.csv"));
        m.mark_done("b", 1, "row-b\n".into(), rec("b/out.csv"));
        assert_eq!(m.reconcile_exports(&root), 1, "b's export is missing");
        assert!(m.is_done("a") && !m.is_done("b"));
        // Attempts survive demotion; the row does not.
        assert_eq!(m.attempts("b"), 1);
        assert_eq!(m.row("b"), None);
        // A size mismatch also demotes.
        std::fs::write(root.join("a/out.csv"), b"123").unwrap();
        assert_eq!(m.reconcile_exports(&root), 1);
        assert!(!m.is_done("a"));
    }

    #[test]
    fn save_is_atomic_and_loadable() {
        let dir = std::env::temp_dir().join("powertrace_test_manifest_save");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.json");
        let m = sample(&["a"]);
        m.save(&p).unwrap();
        assert!(!fsx::tmp_path(&p).exists(), "staging file must be renamed away");
        let back = RunManifest::load(&p).unwrap();
        assert_eq!(back.grid_hash, m.grid_hash);
    }

    #[test]
    fn keeper_persists_every_update() {
        let dir = std::env::temp_dir().join("powertrace_test_manifest_keeper");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.json");
        let keeper = ManifestKeeper::new(sample(&["a", "b"]), p.clone()).unwrap();
        assert_eq!(RunManifest::load(&p).unwrap().done_count(), 0);
        keeper.update(|m| m.mark_done("a", 1, "row\n".into(), Vec::new())).unwrap();
        assert_eq!(RunManifest::load(&p).unwrap().done_count(), 1);
        assert_eq!(keeper.with(|m| m.attempts("a")), 1);
        assert!(keeper.into_inner().is_done("a"));
    }
}
