//! Atomic filesystem primitives for durable exports.
//!
//! Every artifact a reader might consume (summary CSVs, per-cell series,
//! manifests, spec JSON) is staged to a `.tmp` sibling and renamed into
//! place. A rename within one directory is atomic on POSIX, so a crash
//! mid-write leaves either the previous bytes or a `.tmp` that resume
//! logic and readers ignore — never a truncated file with a valid header.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// The staging sibling a writer targets before [`persist`]:
/// `summary.csv` → `summary.csv.tmp`.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically move a staged temp file into its final place.
pub fn persist(tmp: &Path, path: &Path) -> Result<()> {
    std::fs::rename(tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
}

/// Write `bytes` to `path` atomically (stage to `<path>.tmp`, rename),
/// creating parent directories. The `export.write` failpoint fires first,
/// tagged with the file name, so the injection harness can fail any
/// buffered export path on demand.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    super::failpoint::hit("export.write", &name)?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
    }
    let tmp = tmp_path(path);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    persist(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmp_path_appends_suffix() {
        assert_eq!(tmp_path(Path::new("/a/b/summary.csv")), Path::new("/a/b/summary.csv.tmp"));
        assert_eq!(tmp_path(Path::new("manifest.json")), Path::new("manifest.json.tmp"));
    }

    #[test]
    fn atomic_write_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("powertrace_test_fsx");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("out.csv");
        atomic_write(&p, b"a,b\n1,2\n").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"a,b\n1,2\n");
        assert!(!tmp_path(&p).exists(), "staging file must be renamed away");
        // Overwrite is atomic too: the old bytes are fully replaced.
        atomic_write(&p, b"a,b\n3,4\n").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"a,b\n3,4\n");
    }
}
