//! Crash-safe execution layer: run manifests, per-cell fault isolation,
//! atomic exports, and a deterministic failpoint injection harness.
//!
//! Multi-day sweeps and site studies are long-running batch jobs; this
//! module is what makes them survivable. Four pieces compose:
//!
//! * [`fsx`] — atomic file primitives: every durable artifact is staged
//!   to `<name>.tmp` and renamed into place, so a crash never leaves a
//!   plausible-looking truncated CSV or JSON file;
//! * [`manifest`] — the per-run [`RunManifest`]: grid hash, launch
//!   options, and per-cell status (`pending` / `done{row, exports}` /
//!   `failed{attempts, reason}`), rewritten atomically as cells complete.
//!   `powertrace sweep --resume <manifest>` replays `done` rows verbatim
//!   and re-runs the rest — cells are pure functions of `(spec, seed)`,
//!   so the final summary is byte-identical to an uninterrupted run;
//! * [`isolate`] — [`run_isolated`]: each cell under `catch_unwind` with
//!   a bounded retry policy and a soft wall-clock [`Deadline`], so a
//!   poisoned cell is quarantined into the manifest instead of killing
//!   the pool;
//! * [`failpoint`] — named injection sites (`sweep.cell`,
//!   `sweep.cell.window`, `export.write`, `site.variant`, `site.window`)
//!   compiled to no-ops unless the `failpoints` feature is on, where they
//!   can panic / error / stall / abort deterministically — the harness CI
//!   uses to crash a sweep at every site and prove `--resume` heals it.
//!
//! The contract the pieces add up to (documented in
//! `docs/ARCHITECTURE.md` §Failure model): after any crash or quarantine,
//! re-running with `--resume` converges to the same final bytes the
//! uninterrupted run would have produced.

pub mod failpoint;
#[cfg(feature = "host")]
pub mod fsx;
pub mod isolate;
#[cfg(feature = "host")]
pub mod manifest;
#[cfg(feature = "host")]
pub mod merge;
pub mod shutdown;

pub use isolate::{run_isolated, Deadline, Isolated, RetryPolicy};
#[cfg(feature = "host")]
pub use manifest::{CellState, CellStatus, ExportRecord, ManifestKeeper, RunManifest};
