//! Cooperative shutdown: one process-wide flag, checked at the engine's
//! existing yield points.
//!
//! A SIGINT/SIGTERM during a checkpointed run must not lose work or leave
//! an inconsistent resume state. The [`ManifestKeeper`] already rewrites
//! the manifest atomically after every cell, so durability is never the
//! problem — the problem is dying *mid-cell* and counting the interrupt
//! as a failure. This module turns the signal into a request:
//!
//! * [`request`] (called from the signal handler, or by the
//!   `interrupt` failpoint action) sets a global flag;
//! * [`Deadline::check`](crate::robust::Deadline::check) — already called
//!   at every streaming window and lockstep barrier — returns an error
//!   carrying [`INTERRUPT_MARKER`] once the flag is set, so in-flight
//!   cells stop at the next window boundary;
//! * [`run_isolated`](crate::robust::run_isolated) stops retrying, and
//!   the checkpointed runners leave interrupted cells **pending** (never
//!   quarantined, no attempt recorded) and skip cells not yet started;
//! * the runners report the pending count as `interrupted`, and the CLI
//!   prints a `--resume` hint instead of a quarantine list.
//!
//! Everything here is a relaxed atomic — core-safe, no filesystem, no
//! threads. [`install_handlers`] (host-only) wires SIGINT/SIGTERM to
//! [`request`]; a second signal force-exits with status 130 for runs that
//! are wedged somewhere without a yield point.
//!
//! [`ManifestKeeper`]: crate::robust::manifest::ManifestKeeper

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};

static REQUESTED: AtomicBool = AtomicBool::new(false);

/// Substring every shutdown-induced error carries — how the checkpointed
/// runners distinguish "interrupted" from "failed" without a second error
/// channel through `catch_unwind`.
pub const INTERRUPT_MARKER: &str = "shutdown requested";

/// `true` once a shutdown has been requested (signal, failpoint, or API).
pub fn requested() -> bool {
    REQUESTED.load(Ordering::Relaxed)
}

/// Request a cooperative shutdown: running cells stop at their next yield
/// point, queued cells never start. Idempotent; async-signal-safe.
pub fn request() {
    REQUESTED.store(true, Ordering::Relaxed);
}

/// Clear the flag (tests; a server draining one interrupted batch run).
pub fn reset() {
    REQUESTED.store(false, Ordering::Relaxed);
}

/// `Err` (carrying [`INTERRUPT_MARKER`]) once shutdown was requested.
pub fn check() -> Result<()> {
    if requested() {
        bail!("{INTERRUPT_MARKER}: stopping at the next safe point");
    }
    Ok(())
}

/// Was this failure reason produced by a shutdown request (directly or as
/// the root of an error chain)?
pub fn is_interrupt(reason: &str) -> bool {
    reason.contains(INTERRUPT_MARKER)
}

/// Install SIGINT/SIGTERM handlers that call [`request`]. The second
/// signal exits immediately with status 130 (the shell convention for
/// death-by-SIGINT) — the escape hatch when a run is stuck somewhere
/// without a yield point. Call once, from `main`-adjacent code only:
/// plain (non-checkpointed) runs keep the default kill-on-^C behavior.
#[cfg(all(feature = "host", unix))]
pub fn install_handlers() {
    static INSTALLED: AtomicBool = AtomicBool::new(false);
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    unsafe extern "C" fn on_signal(_signum: i32) {
        // Only atomics and _exit in here — the handler must stay
        // async-signal-safe.
        if REQUESTED.swap(true, Ordering::SeqCst) {
            _exit(130);
        }
    }
    extern "C" {
        // Raw libc bindings (the crate carries no libc dependency):
        // `signal(2)` registers a handler, `_exit(2)` is the
        // async-signal-safe process exit.
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        let h = on_signal as unsafe extern "C" fn(i32) as usize;
        signal(SIGINT, h);
        signal(SIGTERM, h);
    }
}

#[cfg(all(feature = "host", unix))]
extern "C" {
    fn _exit(status: i32) -> !;
}

/// No-op on non-unix hosts: runs stay interruptible through the
/// `interrupt` failpoint and [`request`], just not via signals.
#[cfg(all(feature = "host", not(unix)))]
pub fn install_handlers() {}

/// Serialize unit tests that touch (or must observe a clear) global
/// shutdown flag — the flag is process-wide, and `cargo test` threads
/// share the process.
#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_check_reset_roundtrip() {
        let _serial = test_serial();
        reset();
        assert!(!requested());
        check().unwrap();
        request();
        assert!(requested());
        let e = check().unwrap_err();
        assert!(is_interrupt(&format!("{e:#}")));
        reset();
        check().unwrap();
    }
}
