//! Per-cell fault isolation: bounded retries under `catch_unwind` plus a
//! soft wall-clock budget.
//!
//! Cells are pure functions of `(spec, seed)`, so re-running one is
//! byte-equivalent to the first attempt — which makes retry a sound
//! response to *transient* failures (an exhausted file descriptor, a
//! flaky filesystem) while a *deterministic* failure simply fails every
//! attempt and is quarantined with its final reason. Nothing here spawns
//! threads: isolation composes with
//! [`parallel_map_results`](crate::util::threadpool::parallel_map_results),
//! which already keeps one item's panic from tearing down the pool.

use anyhow::{bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

/// Retry / timeout policy for one checkpointed run.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Re-runs after the first attempt (total attempts = `1 + max_retries`).
    pub max_retries: u32,
    /// Soft wall-clock budget per attempt, in seconds (0 = unlimited).
    /// Checked cooperatively at window boundaries in streaming mode;
    /// buffered cells cannot be preempted mid-generation, so the budget
    /// only applies where the engine yields. Off by default — wall-clock
    /// is nondeterministic, and a loaded CI box must not quarantine
    /// healthy cells.
    pub cell_timeout_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 1, cell_timeout_s: 0.0 }
    }
}

/// One attempt's soft deadline, checked cooperatively by the running cell.
pub struct Deadline {
    start: Instant,
    budget_s: f64,
}

impl Deadline {
    pub fn start(budget_s: f64) -> Deadline {
        Deadline { start: Instant::now(), budget_s }
    }

    pub fn unbounded() -> Deadline {
        Deadline::start(0.0)
    }

    /// `Err` once the soft budget is exhausted (never fails for budget 0)
    /// or once a cooperative shutdown was requested
    /// ([`crate::robust::shutdown`]) — the deadline checks sit at exactly
    /// the yield points an interrupt must stop at.
    pub fn check(&self) -> Result<()> {
        crate::robust::shutdown::check()?;
        if self.budget_s > 0.0 {
            let elapsed = self.start.elapsed().as_secs_f64();
            if elapsed > self.budget_s {
                bail!("soft wall-clock budget exceeded ({elapsed:.2}s > {}s)", self.budget_s);
            }
        }
        Ok(())
    }
}

/// Outcome of an isolated, retried execution.
pub enum Isolated<T> {
    /// Some attempt succeeded; `attempts` counts every attempt ever made,
    /// including `prior_attempts` carried over from previous runs.
    Done { value: T, attempts: u32 },
    /// Every attempt failed; `reason` is the last failure (an error chain
    /// or a panic payload).
    Failed { attempts: u32, reason: String },
}

/// Run `f` under `catch_unwind` with the policy's bounded retries. Each
/// attempt gets a fresh [`Deadline`]; panics are captured as failure
/// reasons instead of unwinding into the caller. `prior_attempts` seeds
/// the cumulative attempt count (a resumed run keeps counting where the
/// crashed run's manifest left off).
pub fn run_isolated<T>(
    policy: &RetryPolicy,
    prior_attempts: u32,
    f: impl Fn(&Deadline) -> Result<T>,
) -> Isolated<T> {
    let mut attempts = prior_attempts;
    let mut reason = String::new();
    for _ in 0..policy.max_retries.saturating_add(1) {
        attempts += 1;
        let deadline = Deadline::start(policy.cell_timeout_s);
        match catch_unwind(AssertUnwindSafe(|| f(&deadline))) {
            Ok(Ok(v)) => return Isolated::Done { value: v, attempts },
            Ok(Err(e)) => reason = format!("{e:#}"),
            Err(p) => {
                reason = format!("panicked: {}", crate::util::threadpool::panic_message(&*p));
            }
        }
        // A shutdown request is not a cell failure: retrying would only
        // delay the exit, and the runners classify the attempt as
        // "interrupted" (cell stays pending) rather than quarantining it.
        if crate::robust::shutdown::requested() {
            break;
        }
    }
    Isolated::Failed { attempts, reason }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn succeeds_first_attempt() {
        match run_isolated(&RetryPolicy::default(), 0, |_| Ok(42)) {
            Isolated::Done { value, attempts } => {
                assert_eq!(value, 42);
                assert_eq!(attempts, 1);
            }
            Isolated::Failed { .. } => panic!("expected success"),
        }
    }

    #[test]
    fn retries_deterministic_error_then_quarantines() {
        // The retry loop exits early under a shutdown request; hold the
        // flag's test lock so the shutdown round-trip test can't overlap.
        let _serial = crate::robust::shutdown::test_serial();
        let calls = AtomicU32::new(0);
        let policy = RetryPolicy { max_retries: 2, cell_timeout_s: 0.0 };
        match run_isolated(&policy, 0, |_| -> Result<()> {
            calls.fetch_add(1, Ordering::Relaxed);
            bail!("no such trace file")
        }) {
            Isolated::Failed { attempts, reason } => {
                assert_eq!(attempts, 3);
                assert!(reason.contains("no such trace file"), "{reason}");
            }
            Isolated::Done { .. } => panic!("expected failure"),
        }
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn captures_panics_and_recovers_on_retry() {
        let _serial = crate::robust::shutdown::test_serial();
        let calls = AtomicU32::new(0);
        let policy = RetryPolicy { max_retries: 1, cell_timeout_s: 0.0 };
        // First attempt panics, the retry succeeds — and prior attempts
        // from a previous run accumulate into the reported count.
        match run_isolated(&policy, 2, |_| {
            if calls.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("transient");
            }
            Ok(7)
        }) {
            Isolated::Done { value, attempts } => {
                assert_eq!(value, 7);
                assert_eq!(attempts, 4);
            }
            Isolated::Failed { reason, .. } => panic!("expected recovery, got: {reason}"),
        }
    }

    #[test]
    fn deadline_trips_only_with_budget() {
        let _serial = crate::robust::shutdown::test_serial();
        let d = Deadline::unbounded();
        std::thread::sleep(std::time::Duration::from_millis(5));
        d.check().unwrap();
        let d = Deadline::start(0.001);
        std::thread::sleep(std::time::Duration::from_millis(10));
        let e = d.check().unwrap_err();
        assert!(format!("{e}").contains("budget exceeded"));
    }

    #[test]
    fn timeout_failures_retry_and_quarantine() {
        let _serial = crate::robust::shutdown::test_serial();
        let policy = RetryPolicy { max_retries: 1, cell_timeout_s: 0.001 };
        match run_isolated(&policy, 0, |d| -> Result<()> {
            std::thread::sleep(std::time::Duration::from_millis(10));
            d.check()
        }) {
            Isolated::Failed { attempts, reason } => {
                assert_eq!(attempts, 2);
                assert!(reason.contains("budget exceeded"), "{reason}");
            }
            Isolated::Done { .. } => panic!("expected timeout"),
        }
    }
}
