//! Workload features (paper §2.1): the active-request count `A_t` and its
//! first difference `ΔA_t`, computed on the 250 ms power-sampling grid from
//! the modeled active intervals.
//!
//! `A_t = |{i : t_start_i ≤ t < t_end_i}|` (Eq. 6), `ΔA_t = A_t − A_{t−1}`.

use super::queue::ActiveInterval;

/// Per-timestep feature series, interleaved as the classifier expects:
/// `x_t = (A_t, ΔA_t)`.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureSeries {
    /// Sampling interval (s).
    pub dt_s: f64,
    /// Active-request count per timestep.
    pub a: Vec<f32>,
    /// First difference of `a` (Δa[0] = a[0], i.e. A_{-1} = 0).
    pub da: Vec<f32>,
}

impl FeatureSeries {
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Interleave into `[T, 2]` row-major `(A_t, ΔA_t)` for the classifier.
    pub fn interleaved(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.a.len() * 2);
        for (&a, &da) in self.a.iter().zip(self.da.iter()) {
            out.push(a);
            out.push(da);
        }
        out
    }
}

/// Fill `diff` with the occupancy difference-array for `intervals` on an
/// `n_steps × dt_s` grid (shared by both feature builders below).
fn occupancy_diff(intervals: &[ActiveInterval], n_steps: usize, dt_s: f64, diff: &mut Vec<i32>) {
    assert!(dt_s > 0.0);
    diff.clear();
    diff.resize(n_steps + 1, 0);
    for iv in intervals {
        // A request is active from the timestep its prefill begins until the
        // timestep its final token is generated (paper §2.1).
        let start_bin = (iv.start_s / dt_s).floor();
        let end_bin = (iv.end_s() / dt_s).floor();
        if start_bin >= n_steps as f64 {
            continue;
        }
        let s = start_bin.max(0.0) as usize;
        // end bin is inclusive of the final-token timestep
        let e = (end_bin.max(0.0) as usize + 1).min(n_steps);
        if e > s {
            diff[s] += 1;
            diff[e] -= 1;
        }
    }
}

/// Compute `(A_t, ΔA_t)` on a grid of `n_steps` intervals of `dt_s` seconds.
///
/// Uses a difference-array so the cost is O(requests + timesteps) — this is
/// on the per-server hot path for facility generation.
pub fn features_from_intervals(
    intervals: &[ActiveInterval],
    n_steps: usize,
    dt_s: f64,
) -> FeatureSeries {
    let mut diff = Vec::new();
    occupancy_diff(intervals, n_steps, dt_s, &mut diff);
    let mut a = Vec::with_capacity(n_steps);
    let mut cur = 0i32;
    for &d in diff.iter().take(n_steps) {
        cur += d;
        debug_assert!(cur >= 0);
        a.push(cur as f32);
    }
    let mut da = Vec::with_capacity(n_steps);
    let mut prev = 0.0f32;
    for &x in &a {
        da.push(x - prev);
        prev = x;
    }
    FeatureSeries { dt_s, a, da }
}

/// Allocation-free variant for the batched facility pipeline: writes the
/// classifier-ready interleaved `[T, 2]` feature rows `(A_t, ΔA_t)`
/// directly into `out`, reusing `diff` and `out` capacity across servers.
/// Produces exactly `features_from_intervals(..).interleaved()`.
pub fn features_interleaved_into(
    intervals: &[ActiveInterval],
    n_steps: usize,
    dt_s: f64,
    diff: &mut Vec<i32>,
    out: &mut Vec<f32>,
) {
    occupancy_diff(intervals, n_steps, dt_s, diff);
    out.clear();
    out.reserve(2 * n_steps);
    let mut cur = 0i32;
    let mut prev = 0.0f32;
    for &d in diff.iter().take(n_steps) {
        cur += d;
        debug_assert!(cur >= 0);
        let a = cur as f32;
        out.push(a);
        out.push(a - prev);
        prev = a;
    }
}

/// Compressed occupancy timeline: the sorted steps where `A_t` changes and
/// its value from each step on. O(active requests) memory — independent of
/// the sampling rate — with exact random-access reconstruction of any
/// `(A_t, ΔA_t)` window, which is what lets the streaming facility
/// pipeline drop its per-lane `[T, 2]` feature buffers entirely.
///
/// Built through the same [`occupancy_diff`] used by the full builders, so
/// [`OccupancyEvents::fill_interleaved`] reproduces
/// [`features_interleaved_into`]'s output bit-for-bit over any window
/// partition (integer occupancies convert to f32 exactly).
#[derive(Debug, Clone)]
pub struct OccupancyEvents {
    /// Steps where occupancy changes, strictly ascending.
    steps: Vec<u32>,
    /// Occupancy from `steps[i]` (inclusive) until the next change.
    occ: Vec<i32>,
    n_steps: usize,
}

impl OccupancyEvents {
    /// Compress `intervals` on an `n_steps × dt_s` grid. `diff` is a
    /// reusable scratch difference-array (transient O(n_steps); only the
    /// compressed events are retained).
    pub fn from_intervals_with(
        intervals: &[ActiveInterval],
        n_steps: usize,
        dt_s: f64,
        diff: &mut Vec<i32>,
    ) -> OccupancyEvents {
        occupancy_diff(intervals, n_steps, dt_s, diff);
        let mut steps = Vec::new();
        let mut occ = Vec::new();
        let mut cur = 0i32;
        for (t, &d) in diff.iter().take(n_steps).enumerate() {
            if d != 0 {
                cur += d;
                debug_assert!(cur >= 0);
                steps.push(t as u32);
                occ.push(cur);
            }
        }
        OccupancyEvents { steps, occ, n_steps }
    }

    pub fn from_intervals(
        intervals: &[ActiveInterval],
        n_steps: usize,
        dt_s: f64,
    ) -> OccupancyEvents {
        let mut diff = Vec::new();
        Self::from_intervals_with(intervals, n_steps, dt_s, &mut diff)
    }

    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Number of stored change events (memory is O(this)).
    pub fn n_events(&self) -> usize {
        self.steps.len()
    }

    /// `A_t` for any `t < n_steps` (0 before the first event).
    pub fn occupancy_at(&self, t: usize) -> i32 {
        debug_assert!(t < self.n_steps);
        match self.steps.partition_point(|&s| (s as usize) <= t) {
            0 => 0,
            i => self.occ[i - 1],
        }
    }

    /// Write interleaved `(A_t, ΔA_t)` rows for `t0 .. t0 + n` into
    /// `out[..2n]`. `ΔA_{t0}` is taken against `A_{t0-1}` (`0` at the
    /// series start), exactly as the full-horizon builder computes it —
    /// filling a partition of `0..n_steps` window by window reproduces
    /// [`features_interleaved_into`] byte-for-byte.
    pub fn fill_interleaved(&self, t0: usize, n: usize, out: &mut [f32]) {
        debug_assert!(t0 + n <= self.n_steps, "window {t0}+{n} beyond {}", self.n_steps);
        // First event at or after t0; occupancy just before t0.
        let mut j = self.steps.partition_point(|&s| (s as usize) < t0);
        let mut cur = if j == 0 { 0 } else { self.occ[j - 1] };
        let mut prev = if t0 == 0 { 0.0f32 } else { cur as f32 };
        for rel in 0..n {
            let t = t0 + rel;
            while j < self.steps.len() && self.steps[j] as usize == t {
                cur = self.occ[j];
                j += 1;
            }
            let a = cur as f32;
            out[2 * rel] = a;
            out[2 * rel + 1] = a - prev;
            prev = a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;

    fn iv(start: f64, prefill: f64, decode: f64) -> ActiveInterval {
        ActiveInterval { start_s: start, prefill_s: prefill, decode_s: decode }
    }

    #[test]
    fn single_request_occupancy() {
        // Active on [1.0, 2.0): bins 4..=8 at dt=0.25 (end bin inclusive).
        let f = features_from_intervals(&[iv(1.0, 0.5, 0.5)], 16, 0.25);
        assert_eq!(f.a[3], 0.0);
        for t in 4..=8 {
            assert_eq!(f.a[t], 1.0, "bin {t}");
        }
        assert_eq!(f.a[9], 0.0);
        // ΔA: +1 at entry bin, -1 after exit
        assert_eq!(f.da[4], 1.0);
        assert_eq!(f.da[9], -1.0);
    }

    #[test]
    fn overlapping_requests_sum() {
        let f = features_from_intervals(&[iv(0.0, 0.5, 1.0), iv(0.5, 0.5, 1.0)], 12, 0.25);
        assert_eq!(f.a[0], 1.0);
        assert_eq!(f.a[2], 2.0); // both active at t=0.5..1.5
        assert!(f.a.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn requests_beyond_horizon_are_clipped() {
        let f = features_from_intervals(&[iv(100.0, 1.0, 1.0)], 10, 0.25);
        assert!(f.a.iter().all(|&x| x == 0.0));
        let f = features_from_intervals(&[iv(2.0, 10.0, 10.0)], 10, 0.25);
        assert_eq!(f.a[8], 1.0);
        assert_eq!(f.a[9], 1.0); // clipped at horizon
    }

    #[test]
    fn delta_telescopes_to_a() {
        let f = features_from_intervals(
            &[iv(0.2, 0.3, 0.8), iv(0.9, 0.2, 2.0), iv(1.5, 0.1, 0.4)],
            20,
            0.25,
        );
        let mut acc = 0.0f32;
        for (a, da) in f.a.iter().zip(f.da.iter()) {
            acc += da;
            assert_eq!(acc, *a);
        }
    }

    #[test]
    fn interleaved_layout() {
        let f = FeatureSeries { dt_s: 0.25, a: vec![1.0, 2.0], da: vec![1.0, 1.0] };
        assert_eq!(f.interleaved(), vec![1.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn interleaved_into_matches_reference_builder() {
        let ivs = [iv(0.2, 0.3, 0.8), iv(0.9, 0.2, 2.0), iv(1.5, 0.1, 0.4), iv(100.0, 1.0, 1.0)];
        let mut diff = Vec::new();
        let mut out = vec![99.0f32; 3]; // stale contents must be discarded
        for n_steps in [0usize, 1, 20] {
            features_interleaved_into(&ivs, n_steps, 0.25, &mut diff, &mut out);
            assert_eq!(out, features_from_intervals(&ivs, n_steps, 0.25).interleaved());
        }
    }

    #[test]
    fn events_match_full_builder_over_any_window_partition() {
        let ivs = [iv(0.2, 0.3, 0.8), iv(0.9, 0.2, 2.0), iv(1.5, 0.1, 0.4), iv(3.0, 0.5, 1.5)];
        let n_steps = 40;
        let ev = OccupancyEvents::from_intervals(&ivs, n_steps, 0.25);
        let mut diff = Vec::new();
        let mut reference = Vec::new();
        features_interleaved_into(&ivs, n_steps, 0.25, &mut diff, &mut reference);
        // Window sizes that do and don't divide n_steps, plus size 1.
        for window in [1usize, 7, 8, 40, 64] {
            let mut got = vec![0.0f32; 2 * n_steps];
            let mut t0 = 0;
            while t0 < n_steps {
                let n = window.min(n_steps - t0);
                ev.fill_interleaved(t0, n, &mut got[2 * t0..2 * (t0 + n)]);
                t0 += n;
            }
            for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "window {window} element {i}");
            }
        }
        // Random access agrees with the prefix-summed series.
        for t in 0..n_steps {
            assert_eq!(ev.occupancy_at(t) as f32, reference[2 * t], "A_{t}");
        }
    }

    #[test]
    fn events_are_compact() {
        // 3 requests → at most 6 change events, regardless of grid size.
        let ivs = [iv(1.0, 0.5, 0.5), iv(5.0, 0.5, 0.5), iv(9.0, 0.5, 0.5)];
        let ev = OccupancyEvents::from_intervals(&ivs, 10_000, 0.25);
        assert!(ev.n_events() <= 6, "{} events", ev.n_events());
        assert_eq!(ev.n_steps(), 10_000);
    }

    #[test]
    fn prop_events_reconstruct_random_interval_sets() {
        check("occupancy events == diff-array features", |rng| {
            let n = 1 + rng.below(30);
            let ivs: Vec<ActiveInterval> = (0..n)
                .map(|_| iv(rng.range(0.0, 50.0), rng.range(0.01, 2.0), rng.range(0.01, 20.0)))
                .collect();
            let n_steps = 1 + rng.below(300);
            let mut diff = Vec::new();
            let mut reference = Vec::new();
            features_interleaved_into(&ivs, n_steps, 0.25, &mut diff, &mut reference);
            let ev = OccupancyEvents::from_intervals(&ivs, n_steps, 0.25);
            let window = 1 + rng.below(n_steps);
            let mut got = vec![0.0f32; 2 * n_steps];
            let mut t0 = 0;
            while t0 < n_steps {
                let w = window.min(n_steps - t0);
                ev.fill_interleaved(t0, w, &mut got[2 * t0..2 * (t0 + w)]);
                t0 += w;
            }
            assert_eq!(got, reference);
        });
    }

    #[test]
    fn prop_a_nonnegative_and_bounded_by_requests() {
        check("A_t bounded", |rng| {
            let n = 1 + rng.below(40);
            let ivs: Vec<ActiveInterval> = (0..n)
                .map(|_| iv(rng.range(0.0, 50.0), rng.range(0.01, 2.0), rng.range(0.01, 20.0)))
                .collect();
            let f = features_from_intervals(&ivs, 400, 0.25);
            for &a in &f.a {
                assert!(a >= 0.0 && a <= n as f32);
            }
            // sum of positive ΔA equals number of requests entering horizon
            let entering = ivs.iter().filter(|v| v.start_s < 100.0).count() as f32;
            let pos: f32 = f.da.iter().filter(|&&d| d > 0.0).sum();
            assert!(pos <= entering);
        });
    }
}
