//! The throughput surrogate (paper §3.3): a lightweight model of continuous
//! batching that turns a request arrival schedule into per-timestep workload
//! features `(A_t, ΔA_t)` without coupling to a serving-system
//! implementation.
//!
//! Query lifetime = prefill + decode, with
//!   `log(TTFT) = α₀ + α₁·log(n_in + 1) + ε,  ε ~ N(0, σ_TTFT²)`  (Eq. 4)
//!   `log(TBT) ~ N(μ_logTBT, σ_logTBT²)`                           (Eq. 5)
//! and a FIFO queue with a fixed batch capacity (64 in the paper).

pub mod calibrate;
pub mod features;
pub mod queue;

pub use calibrate::{fit_surrogate, DurationSamples};
pub use features::{
    features_from_intervals, features_interleaved_into, FeatureSeries, OccupancyEvents,
};
pub use queue::{simulate_queue, simulate_queue_policy, ActiveInterval, QueuePolicy};

use crate::util::rng::Rng;

/// Calibrated surrogate parameters for one serving configuration
/// (α₀, α₁, σ_TTFT, μ_logTBT, σ_logTBT — paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SurrogateParams {
    pub alpha0: f64,
    pub alpha1: f64,
    pub sigma_ttft: f64,
    pub mu_log_tbt: f64,
    pub sigma_log_tbt: f64,
}

impl SurrogateParams {
    /// Sample a prefill duration (TTFT) for a prompt of `n_in` tokens.
    pub fn sample_ttft(&self, n_in: u32, rng: &mut Rng) -> f64 {
        let mean = self.alpha0 + self.alpha1 * ((n_in as f64) + 1.0).ln();
        (mean + self.sigma_ttft * rng.normal()).exp()
    }

    /// Expected TTFT (median of the lognormal).
    pub fn median_ttft(&self, n_in: u32) -> f64 {
        (self.alpha0 + self.alpha1 * ((n_in as f64) + 1.0).ln()).exp()
    }

    /// Sample an inter-token latency (TBT).
    pub fn sample_tbt(&self, rng: &mut Rng) -> f64 {
        (self.mu_log_tbt + self.sigma_log_tbt * rng.normal()).exp()
    }

    /// Median TBT.
    pub fn median_tbt(&self) -> f64 {
        self.mu_log_tbt.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_grows_with_prompt_length() {
        let p = SurrogateParams {
            alpha0: -3.0,
            alpha1: 0.9,
            sigma_ttft: 0.0,
            mu_log_tbt: -4.0,
            sigma_log_tbt: 0.0,
        };
        assert!(p.median_ttft(2048) > p.median_ttft(128));
        // superlinear in n_in when alpha1 close to 1: ratio of TTFTs >
        // ratio^0.8 at least
        let r = p.median_ttft(4096) / p.median_ttft(512);
        assert!(r > 8f64.powf(0.8), "ratio {r}");
    }

    #[test]
    fn deterministic_when_sigma_zero() {
        let p = SurrogateParams {
            alpha0: -2.0,
            alpha1: 1.0,
            sigma_ttft: 0.0,
            mu_log_tbt: -4.0,
            sigma_log_tbt: 0.0,
        };
        let mut rng = Rng::new(5);
        assert_eq!(p.sample_ttft(100, &mut rng), p.median_ttft(100));
        assert_eq!(p.sample_tbt(&mut rng), p.median_tbt());
    }

    #[test]
    fn sampling_median_matches() {
        let p = SurrogateParams {
            alpha0: -2.0,
            alpha1: 0.8,
            sigma_ttft: 0.4,
            mu_log_tbt: -4.0,
            sigma_log_tbt: 0.3,
        };
        let mut rng = Rng::new(6);
        let mut ttfts: Vec<f64> = (0..20_001).map(|_| p.sample_ttft(256, &mut rng)).collect();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = ttfts[ttfts.len() / 2];
        assert!((med / p.median_ttft(256) - 1.0).abs() < 0.05, "median ratio");
    }
}
