//! FIFO continuous-batching queue model (paper §3.3): requests are admitted
//! in arrival order into a fixed number of batch slots; request *i* begins
//! at `max(t_i, earliest available slot)`, incurs its TTFT, then decodes for
//! `n_out × TBT` seconds.
//!
//! The token-level workload axis adds an optional **token budget**
//! ([`QueuePolicy`]): admission then also requires the running batch's
//! total token weight (`n_in + n_out` per live request, clamped to the
//! budget) to fit, so long-prompt/long-output traffic serializes even with
//! free slots — occupancy derives from token service demand, not just
//! request count. Without a budget, [`simulate_queue_policy`] dispatches to
//! the unchanged [`simulate_queue`], so every rate-driven workload keeps
//! its bit-identical behavior.

use super::SurrogateParams;
use crate::util::rng::Rng;
use crate::workload::Schedule;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One request's modeled lifetime (used for features and Fig 5 CDFs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActiveInterval {
    /// When execution began (≥ arrival time).
    pub start_s: f64,
    /// Prefill duration (TTFT).
    pub prefill_s: f64,
    /// Decode duration (n_out × TBT).
    pub decode_s: f64,
}

impl ActiveInterval {
    pub fn end_s(&self) -> f64 {
        self.start_s + self.prefill_s + self.decode_s
    }
}

// f64 ordering wrapper for the slot heap (end times are always finite).
#[derive(PartialEq)]
struct F(f64);
impl Eq for F {}
impl PartialOrd for F {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("finite")
    }
}

/// Simulate the FIFO queue, returning each request's [`ActiveInterval`]
/// (parallel to `schedule`, which must be time-sorted).
pub fn simulate_queue(
    schedule: &Schedule,
    params: &SurrogateParams,
    max_batch: usize,
    rng: &mut Rng,
) -> Vec<ActiveInterval> {
    assert!(max_batch > 0, "simulate_queue: max_batch must be positive");
    // Min-heap of slot-free times; absent entries mean free-now.
    let mut slots: BinaryHeap<Reverse<F>> = BinaryHeap::with_capacity(max_batch);
    let mut out = Vec::with_capacity(schedule.len());
    for req in schedule {
        let free_at = if slots.len() < max_batch {
            req.arrival_s
        } else {
            let Reverse(F(earliest)) = slots.pop().expect("nonempty");
            earliest
        };
        let start = req.arrival_s.max(free_at);
        let prefill = params.sample_ttft(req.n_in, rng);
        let tbt = params.sample_tbt(rng);
        let decode = req.n_out as f64 * tbt;
        let iv = ActiveInterval { start_s: start, prefill_s: prefill, decode_s: decode };
        slots.push(Reverse(F(iv.end_s())));
        out.push(iv);
    }
    out
}

/// Admission policy for the queue surrogate: a slot cap plus an optional
/// per-batch token budget (continuous-batching token packing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuePolicy {
    /// Maximum concurrently running requests (batch slots).
    pub max_batch: usize,
    /// Maximum Σ (n_in + n_out) over running requests; `None` = unlimited.
    pub token_budget: Option<u64>,
}

impl QueuePolicy {
    /// The classic slot-only policy (exactly [`simulate_queue`]'s model).
    pub fn slots(max_batch: usize) -> QueuePolicy {
        QueuePolicy { max_batch, token_budget: None }
    }
}

/// Simulate the FIFO queue under a [`QueuePolicy`]. With no token budget
/// this *is* [`simulate_queue`] — same arithmetic, same RNG consumption —
/// so rate-driven workloads are unaffected by policy threading.
pub fn simulate_queue_policy(
    schedule: &Schedule,
    params: &SurrogateParams,
    policy: QueuePolicy,
    rng: &mut Rng,
) -> Vec<ActiveInterval> {
    match policy.token_budget {
        None => simulate_queue(schedule, params, policy.max_batch, rng),
        Some(budget) => simulate_queue_budgeted(schedule, params, policy.max_batch, budget, rng),
    }
}

/// Token-budget variant: a min-heap of `(end time, token weight)` slots and
/// a running `used` sum. Admission pops the earliest-ending slots (raising
/// the start floor to their end times — FIFO order is preserved) until both
/// the slot cap and the budget admit the request. Per-request weight is
/// clamped to the budget so an oversized request still runs, alone.
fn simulate_queue_budgeted(
    schedule: &Schedule,
    params: &SurrogateParams,
    max_batch: usize,
    budget: u64,
    rng: &mut Rng,
) -> Vec<ActiveInterval> {
    assert!(max_batch > 0, "simulate_queue: max_batch must be positive");
    assert!(budget > 0, "simulate_queue: token budget must be positive");
    let mut slots: BinaryHeap<Reverse<(F, u64)>> = BinaryHeap::with_capacity(max_batch);
    let mut used: u64 = 0;
    let mut out = Vec::with_capacity(schedule.len());
    for req in schedule {
        let w = (req.n_in as u64 + req.n_out as u64).min(budget);
        let mut free_at = req.arrival_s;
        while slots.len() >= max_batch || used + w > budget {
            let Reverse((F(end), tok)) = slots.pop().expect("constraints imply occupied slots");
            used -= tok;
            free_at = free_at.max(end);
        }
        let start = free_at;
        let prefill = params.sample_ttft(req.n_in, rng);
        let tbt = params.sample_tbt(rng);
        let decode = req.n_out as f64 * tbt;
        let iv = ActiveInterval { start_s: start, prefill_s: prefill, decode_s: decode };
        slots.push(Reverse((F(iv.end_s()), w)));
        used += w;
        out.push(iv);
    }
    out
}

/// Batch occupancy over time derived from intervals — used by invariant
/// tests ("queue never exceeds the batch cap").
pub fn max_concurrency(intervals: &[ActiveInterval]) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for iv in intervals {
        events.push((iv.start_s, 1));
        events.push((iv.end_s(), -1));
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut max = 0i32;
    for (_, d) in events {
        cur += d;
        max = max.max(cur);
    }
    max as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::workload::{poisson_arrivals, LengthSampler, Request};

    fn det_params() -> SurrogateParams {
        SurrogateParams {
            alpha0: -2.0,
            alpha1: 0.7,
            sigma_ttft: 0.0,
            mu_log_tbt: (0.01f64).ln(),
            sigma_log_tbt: 0.0,
        }
    }

    #[test]
    fn uncontended_requests_start_at_arrival() {
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 100, n_out: 10 },
            Request { arrival_s: 100.0, n_in: 100, n_out: 10 },
        ];
        let mut rng = Rng::new(1);
        let ivs = simulate_queue(&sched, &det_params(), 64, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert_eq!(ivs[1].start_s, 100.0);
        // decode = 10 tokens × 0.01 s
        assert!((ivs[0].decode_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn single_slot_serializes_requests() {
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
        ];
        let mut rng = Rng::new(2);
        let ivs = simulate_queue(&sched, &det_params(), 1, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert!((ivs[1].start_s - ivs[0].end_s()).abs() < 1e-9);
        assert!((ivs[2].start_s - ivs[1].end_s()).abs() < 1e-9);
        assert_eq!(max_concurrency(&ivs), 1);
    }

    #[test]
    fn fifo_order_is_respected() {
        // With 2 slots and 4 simultaneous arrivals, requests 3 and 4 must
        // start when 1 and 2 finish, in order.
        let sched: Schedule =
            (0..4).map(|_| Request { arrival_s: 0.0, n_in: 100, n_out: 50 }).collect();
        let mut rng = Rng::new(3);
        let ivs = simulate_queue(&sched, &det_params(), 2, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert_eq!(ivs[1].start_s, 0.0);
        assert!(ivs[2].start_s >= ivs[0].end_s().min(ivs[1].end_s()) - 1e-9);
        assert!(ivs[3].start_s >= ivs[2].start_s);
    }

    #[test]
    fn prop_concurrency_never_exceeds_batch() {
        check("queue respects batch cap", |rng| {
            let cap = 1 + rng.below(64);
            let rate = rng.range(0.5, 20.0);
            let lengths = LengthSampler::fixed(256, 64);
            let mut local = rng.clone();
            let sched = poisson_arrivals(rate, 120.0, &lengths, &mut local);
            if sched.is_empty() {
                return;
            }
            let ivs = simulate_queue(&sched, &det_params(), cap, &mut local);
            assert!(max_concurrency(&ivs) <= cap, "cap {cap}");
            // starts never precede arrivals
            for (r, iv) in sched.iter().zip(&ivs) {
                assert!(iv.start_s >= r.arrival_s - 1e-9);
                assert!(iv.prefill_s > 0.0 && iv.decode_s > 0.0);
            }
        });
    }

    /// Token-weighted concurrency: max Σ w over instants, with each
    /// request's weight `min(n_in + n_out, budget)`.
    fn max_token_load(schedule: &Schedule, ivs: &[ActiveInterval], budget: u64) -> u64 {
        let mut events: Vec<(f64, i64)> = Vec::with_capacity(ivs.len() * 2);
        for (r, iv) in schedule.iter().zip(ivs) {
            let w = (r.n_in as u64 + r.n_out as u64).min(budget) as i64;
            events.push((iv.start_s, w));
            events.push((iv.end_s(), -w));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut cur = 0i64;
        let mut max = 0i64;
        for (_, d) in events {
            cur += d;
            max = max.max(cur);
        }
        max as u64
    }

    #[test]
    fn no_budget_policy_is_bitwise_the_plain_queue() {
        let lengths = LengthSampler::fixed(256, 64);
        let mut rng = Rng::new(21);
        let sched = poisson_arrivals(4.0, 200.0, &lengths, &mut rng);
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        let plain = simulate_queue(&sched, &det_params(), 8, &mut ra);
        let policy = simulate_queue_policy(&sched, &det_params(), QueuePolicy::slots(8), &mut rb);
        assert_eq!(plain.len(), policy.len());
        for (a, b) in plain.iter().zip(&policy) {
            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits());
            assert_eq!(a.prefill_s.to_bits(), b.prefill_s.to_bits());
            assert_eq!(a.decode_s.to_bits(), b.decode_s.to_bits());
        }
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn huge_budget_matches_the_plain_queue_bitwise() {
        let lengths = LengthSampler::fixed(256, 64);
        let mut rng = Rng::new(22);
        let sched = poisson_arrivals(4.0, 200.0, &lengths, &mut rng);
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        let plain = simulate_queue(&sched, &det_params(), 8, &mut ra);
        let pol = QueuePolicy { max_batch: 8, token_budget: Some(u64::MAX) };
        let budgeted = simulate_queue_policy(&sched, &det_params(), pol, &mut rb);
        for (a, b) in plain.iter().zip(&budgeted) {
            assert_eq!(a.start_s.to_bits(), b.start_s.to_bits());
        }
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn token_budget_serializes_wide_requests_despite_free_slots() {
        // Two 200-token requests, budget 300: the second must wait for the
        // first even though 8 slots are free.
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
        ];
        let mut rng = Rng::new(3);
        let pol = QueuePolicy { max_batch: 8, token_budget: Some(300) };
        let ivs = simulate_queue_policy(&sched, &det_params(), pol, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert!((ivs[1].start_s - ivs[0].end_s()).abs() < 1e-9);
    }

    #[test]
    fn oversized_request_still_runs_alone() {
        // A request wider than the whole budget is clamped to it: it runs
        // (alone), rather than deadlocking admission.
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 5000, n_out: 5000 },
            Request { arrival_s: 0.0, n_in: 10, n_out: 10 },
        ];
        let mut rng = Rng::new(4);
        let pol = QueuePolicy { max_batch: 8, token_budget: Some(100) };
        let ivs = simulate_queue_policy(&sched, &det_params(), pol, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        // The small request must wait: the wide one holds the full budget.
        assert!((ivs[1].start_s - ivs[0].end_s()).abs() < 1e-9);
    }

    #[test]
    fn prop_budget_bounds_token_load_and_serves_everything() {
        check("token budget bounds load", |rng| {
            let cap = 1 + rng.below(16);
            let budget = 64 + rng.below(4096) as u64;
            let rate = rng.range(0.5, 10.0);
            let n_in = 1 + rng.below(512) as u32;
            let n_out = 1 + rng.below(128) as u32;
            let lengths = LengthSampler::fixed(n_in, n_out);
            let mut local = rng.clone();
            let sched = poisson_arrivals(rate, 120.0, &lengths, &mut local);
            if sched.is_empty() {
                return;
            }
            let pol = QueuePolicy { max_batch: cap, token_budget: Some(budget) };
            let ivs = simulate_queue_policy(&sched, &det_params(), pol, &mut local);
            // Every request is served, exactly once, never dropped.
            assert_eq!(ivs.len(), sched.len());
            assert!(max_concurrency(&ivs) <= cap, "cap {cap}");
            assert!(max_token_load(&sched, &ivs, budget) <= budget, "budget {budget}");
            for (r, iv) in sched.iter().zip(&ivs) {
                assert!(iv.start_s >= r.arrival_s - 1e-9);
                // Service time depends only on the request, not the policy:
                // decode = n_out × TBT exactly (σ = 0 here).
                assert!((iv.decode_s - r.n_out as f64 * 0.01).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn prop_work_conserving_when_uncontended() {
        // If concurrency stays below cap, every request starts at arrival.
        check("work conserving", |rng| {
            let lengths = LengthSampler::fixed(128, 16);
            let mut local = rng.clone();
            let sched = poisson_arrivals(0.2, 300.0, &lengths, &mut local);
            let ivs = simulate_queue(&sched, &det_params(), 64, &mut local);
            if max_concurrency(&ivs) < 64 {
                for (r, iv) in sched.iter().zip(&ivs) {
                    assert!((iv.start_s - r.arrival_s).abs() < 1e-9);
                }
            }
        });
    }
}
