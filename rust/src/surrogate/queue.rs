//! FIFO continuous-batching queue model (paper §3.3): requests are admitted
//! in arrival order into a fixed number of batch slots; request *i* begins
//! at `max(t_i, earliest available slot)`, incurs its TTFT, then decodes for
//! `n_out × TBT` seconds.

use super::SurrogateParams;
use crate::util::rng::Rng;
use crate::workload::Schedule;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One request's modeled lifetime (used for features and Fig 5 CDFs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActiveInterval {
    /// When execution began (≥ arrival time).
    pub start_s: f64,
    /// Prefill duration (TTFT).
    pub prefill_s: f64,
    /// Decode duration (n_out × TBT).
    pub decode_s: f64,
}

impl ActiveInterval {
    pub fn end_s(&self) -> f64 {
        self.start_s + self.prefill_s + self.decode_s
    }
}

// f64 ordering wrapper for the slot heap (end times are always finite).
#[derive(PartialEq)]
struct F(f64);
impl Eq for F {}
impl PartialOrd for F {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("finite")
    }
}

/// Simulate the FIFO queue, returning each request's [`ActiveInterval`]
/// (parallel to `schedule`, which must be time-sorted).
pub fn simulate_queue(
    schedule: &Schedule,
    params: &SurrogateParams,
    max_batch: usize,
    rng: &mut Rng,
) -> Vec<ActiveInterval> {
    assert!(max_batch > 0, "simulate_queue: max_batch must be positive");
    // Min-heap of slot-free times; absent entries mean free-now.
    let mut slots: BinaryHeap<Reverse<F>> = BinaryHeap::with_capacity(max_batch);
    let mut out = Vec::with_capacity(schedule.len());
    for req in schedule {
        let free_at = if slots.len() < max_batch {
            req.arrival_s
        } else {
            let Reverse(F(earliest)) = slots.pop().expect("nonempty");
            earliest
        };
        let start = req.arrival_s.max(free_at);
        let prefill = params.sample_ttft(req.n_in, rng);
        let tbt = params.sample_tbt(rng);
        let decode = req.n_out as f64 * tbt;
        let iv = ActiveInterval { start_s: start, prefill_s: prefill, decode_s: decode };
        slots.push(Reverse(F(iv.end_s())));
        out.push(iv);
    }
    out
}

/// Batch occupancy over time derived from intervals — used by invariant
/// tests ("queue never exceeds the batch cap").
pub fn max_concurrency(intervals: &[ActiveInterval]) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for iv in intervals {
        events.push((iv.start_s, 1));
        events.push((iv.end_s(), -1));
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut max = 0i32;
    for (_, d) in events {
        cur += d;
        max = max.max(cur);
    }
    max as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::workload::{poisson_arrivals, LengthSampler, Request};

    fn det_params() -> SurrogateParams {
        SurrogateParams {
            alpha0: -2.0,
            alpha1: 0.7,
            sigma_ttft: 0.0,
            mu_log_tbt: (0.01f64).ln(),
            sigma_log_tbt: 0.0,
        }
    }

    #[test]
    fn uncontended_requests_start_at_arrival() {
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 100, n_out: 10 },
            Request { arrival_s: 100.0, n_in: 100, n_out: 10 },
        ];
        let mut rng = Rng::new(1);
        let ivs = simulate_queue(&sched, &det_params(), 64, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert_eq!(ivs[1].start_s, 100.0);
        // decode = 10 tokens × 0.01 s
        assert!((ivs[0].decode_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn single_slot_serializes_requests() {
        let sched = vec![
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
            Request { arrival_s: 0.0, n_in: 100, n_out: 100 },
        ];
        let mut rng = Rng::new(2);
        let ivs = simulate_queue(&sched, &det_params(), 1, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert!((ivs[1].start_s - ivs[0].end_s()).abs() < 1e-9);
        assert!((ivs[2].start_s - ivs[1].end_s()).abs() < 1e-9);
        assert_eq!(max_concurrency(&ivs), 1);
    }

    #[test]
    fn fifo_order_is_respected() {
        // With 2 slots and 4 simultaneous arrivals, requests 3 and 4 must
        // start when 1 and 2 finish, in order.
        let sched: Schedule =
            (0..4).map(|_| Request { arrival_s: 0.0, n_in: 100, n_out: 50 }).collect();
        let mut rng = Rng::new(3);
        let ivs = simulate_queue(&sched, &det_params(), 2, &mut rng);
        assert_eq!(ivs[0].start_s, 0.0);
        assert_eq!(ivs[1].start_s, 0.0);
        assert!(ivs[2].start_s >= ivs[0].end_s().min(ivs[1].end_s()) - 1e-9);
        assert!(ivs[3].start_s >= ivs[2].start_s);
    }

    #[test]
    fn prop_concurrency_never_exceeds_batch() {
        check("queue respects batch cap", |rng| {
            let cap = 1 + rng.below(64);
            let rate = rng.range(0.5, 20.0);
            let lengths = LengthSampler::fixed(256, 64);
            let mut local = rng.clone();
            let sched = poisson_arrivals(rate, 120.0, &lengths, &mut local);
            if sched.is_empty() {
                return;
            }
            let ivs = simulate_queue(&sched, &det_params(), cap, &mut local);
            assert!(max_concurrency(&ivs) <= cap, "cap {cap}");
            // starts never precede arrivals
            for (r, iv) in sched.iter().zip(&ivs) {
                assert!(iv.start_s >= r.arrival_s - 1e-9);
                assert!(iv.prefill_s > 0.0 && iv.decode_s > 0.0);
            }
        });
    }

    #[test]
    fn prop_work_conserving_when_uncontended() {
        // If concurrency stays below cap, every request starts at arrival.
        check("work conserving", |rng| {
            let lengths = LengthSampler::fixed(128, 16);
            let mut local = rng.clone();
            let sched = poisson_arrivals(0.2, 300.0, &lengths, &mut local);
            let ivs = simulate_queue(&sched, &det_params(), 64, &mut local);
            if max_concurrency(&ivs) < 64 {
                for (r, iv) in sched.iter().zip(&ivs) {
                    assert!((iv.start_s - r.arrival_s).abs() < 1e-9);
                }
            }
        });
    }
}
