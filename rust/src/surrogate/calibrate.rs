//! Surrogate calibration: fit (α₀, α₁, σ_TTFT, μ_logTBT, σ_logTBT) from
//! observed request lifetimes (paper §3.3: "estimated per configuration from
//! measured traces, but they can also be obtained from a small benchmark
//! sweep or supplied directly from deployment SLOs/SLAs").
//!
//! TTFT is fit by ordinary least squares in log–log space; TBT by the
//! sample mean/std of log inter-token latency.

use super::SurrogateParams;
use anyhow::{ensure, Result};

/// Observed per-request durations from a measured trace (or the testbed's
/// ground-truth logs): prompt length, prefill seconds, decode seconds,
/// output tokens.
#[derive(Debug, Clone, Default)]
pub struct DurationSamples {
    pub n_in: Vec<u32>,
    pub prefill_s: Vec<f64>,
    pub n_out: Vec<u32>,
    pub decode_s: Vec<f64>,
}

impl DurationSamples {
    pub fn push(&mut self, n_in: u32, prefill_s: f64, n_out: u32, decode_s: f64) {
        self.n_in.push(n_in);
        self.prefill_s.push(prefill_s);
        self.n_out.push(n_out);
        self.decode_s.push(decode_s);
    }

    pub fn len(&self) -> usize {
        self.n_in.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n_in.is_empty()
    }
}

/// Fit surrogate parameters from duration samples.
pub fn fit_surrogate(samples: &DurationSamples) -> Result<SurrogateParams> {
    ensure!(samples.len() >= 8, "need at least 8 samples to calibrate, got {}", samples.len());
    ensure!(
        samples.prefill_s.iter().all(|&x| x > 0.0) && samples.decode_s.iter().all(|&x| x > 0.0),
        "durations must be positive"
    );

    // --- TTFT: OLS of log(ttft) on log(n_in + 1) ---
    let xs: Vec<f64> = samples.n_in.iter().map(|&n| ((n as f64) + 1.0).ln()).collect();
    let ys: Vec<f64> = samples.prefill_s.iter().map(|&t| t.ln()).collect();
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let (alpha0, alpha1) = if sxx < 1e-9 {
        // Degenerate design (constant prompt length): intercept-only model.
        (my, 0.0)
    } else {
        let a1 = sxy / sxx;
        (my - a1 * mx, a1)
    };
    let resid_var: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| {
            let e = y - (alpha0 + alpha1 * x);
            e * e
        })
        .sum::<f64>()
        / n;

    // --- TBT: moments of log(decode_s / n_out) ---
    let log_tbt: Vec<f64> = samples
        .decode_s
        .iter()
        .zip(&samples.n_out)
        .map(|(&d, &n_out)| (d / (n_out.max(1) as f64)).ln())
        .collect();
    let mu = log_tbt.iter().sum::<f64>() / n;
    let var = log_tbt.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / n;

    Ok(SurrogateParams {
        alpha0,
        alpha1,
        sigma_ttft: resid_var.sqrt(),
        mu_log_tbt: mu,
        sigma_log_tbt: var.sqrt(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_planted_parameters() {
        let truth = SurrogateParams {
            alpha0: -2.5,
            alpha1: 0.85,
            sigma_ttft: 0.15,
            mu_log_tbt: -4.2,
            sigma_log_tbt: 0.25,
        };
        let mut rng = Rng::new(41);
        let mut s = DurationSamples::default();
        for _ in 0..4000 {
            let n_in = rng.lognormal(5.5, 0.8).max(1.0) as u32;
            let n_out = rng.lognormal(4.5, 0.5).max(1.0) as u32;
            let ttft = truth.sample_ttft(n_in, &mut rng);
            let tbt = truth.sample_tbt(&mut rng);
            s.push(n_in, ttft, n_out, n_out as f64 * tbt);
        }
        let fit = fit_surrogate(&s).unwrap();
        assert!((fit.alpha0 - truth.alpha0).abs() < 0.1, "alpha0 {}", fit.alpha0);
        assert!((fit.alpha1 - truth.alpha1).abs() < 0.03, "alpha1 {}", fit.alpha1);
        assert!((fit.sigma_ttft - truth.sigma_ttft).abs() < 0.03);
        assert!((fit.mu_log_tbt - truth.mu_log_tbt).abs() < 0.02);
        assert!((fit.sigma_log_tbt - truth.sigma_log_tbt).abs() < 0.02);
    }

    #[test]
    fn fits_nonlinear_truth_reasonably() {
        // Testbed truth is a power law with interference — the log-linear
        // fit should still predict medians within ~30% over the data range.
        let mut rng = Rng::new(42);
        let mut s = DurationSamples::default();
        for _ in 0..2000 {
            let n_in = rng.lognormal(5.5, 0.8).max(8.0) as u32;
            let ttft = 0.25 * ((n_in as f64) / 512.0).powf(1.15) * rng.lognormal(0.0, 0.1);
            let n_out = 100u32;
            s.push(n_in, ttft, n_out, n_out as f64 * 0.015 * rng.lognormal(0.0, 0.1));
        }
        let fit = fit_surrogate(&s).unwrap();
        for n_in in [128u32, 512, 2048] {
            let truth = 0.25 * ((n_in as f64) / 512.0).powf(1.15);
            let pred = fit.median_ttft(n_in);
            assert!(
                (pred / truth - 1.0).abs() < 0.3,
                "n_in={n_in}: pred {pred} vs truth {truth}"
            );
        }
    }

    #[test]
    fn constant_prompt_length_degenerates_gracefully() {
        let mut s = DurationSamples::default();
        for _ in 0..20 {
            s.push(512, 0.3, 100, 1.5);
        }
        let fit = fit_surrogate(&s).unwrap();
        assert_eq!(fit.alpha1, 0.0);
        assert!((fit.median_ttft(512) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn rejects_insufficient_or_invalid() {
        let mut s = DurationSamples::default();
        s.push(10, 0.1, 10, 0.1);
        assert!(fit_surrogate(&s).is_err());
        let mut bad = DurationSamples::default();
        for _ in 0..10 {
            bad.push(10, -0.1, 10, 0.1);
        }
        assert!(fit_surrogate(&bad).is_err());
    }
}
