//! Splitwise-style phase look-up-table baseline (paper §4.3).
//!
//! Mirrors the structure of the public Splitwise performance model: each
//! timestep is labeled with a phase — idle, prompt (prefill-only),
//! decode-only, or mixed — and node power is the active-GPU TDP scaled by a
//! fixed per-phase ratio plus idle power for inactive GPUs. As in the
//! paper, this is a *structurally matched LUT surrogate*: phase power is a
//! constant per phase, so intermediate occupancy levels are unrepresentable
//! — exactly the failure mode Fig. 1 / Table 2 demonstrate.

use crate::catalog::{Catalog, ServerConfig};
use crate::surrogate::ActiveInterval;

/// Phase labels in the LUT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Idle,
    Prompt,
    Decode,
    Mixed,
}

/// Per-phase power ratios (fraction of per-GPU TDP for the active TP
/// group). Defaults follow the Splitwise characterization's shape:
/// prompt ≈ 85–90% of TDP, decode ≈ 50%, mixed treated as prompt-like with
/// a small bump.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LutRatios {
    pub prompt: f64,
    pub decode: f64,
    pub mixed: f64,
}

impl Default for LutRatios {
    fn default() -> Self {
        LutRatios { prompt: 0.87, decode: 0.50, mixed: 0.92 }
    }
}

/// The LUT baseline power model.
#[derive(Debug, Clone)]
pub struct LutBaseline {
    pub ratios: LutRatios,
}

impl Default for LutBaseline {
    fn default() -> Self {
        LutBaseline { ratios: LutRatios::default() }
    }
}

impl LutBaseline {
    /// Label each timestep with a phase from the modeled active intervals.
    pub fn phases(intervals: &[ActiveInterval], n_steps: usize, dt_s: f64) -> Vec<Phase> {
        // Difference arrays over prefill spans and whole-active spans.
        let mut pre = vec![0i32; n_steps + 1];
        let mut act = vec![0i32; n_steps + 1];
        let mark = |d: &mut Vec<i32>, a: f64, b: f64| {
            let s = (a / dt_s).floor().max(0.0) as usize;
            let e = ((b / dt_s).floor() as usize + 1).min(n_steps);
            if s < n_steps && e > s {
                d[s] += 1;
                d[e] -= 1;
            }
        };
        for iv in intervals {
            mark(&mut act, iv.start_s, iv.end_s());
            mark(&mut pre, iv.start_s, iv.start_s + iv.prefill_s);
        }
        let mut out = Vec::with_capacity(n_steps);
        let (mut np, mut na) = (0i32, 0i32);
        for t in 0..n_steps {
            np += pre[t];
            na += act[t];
            out.push(match (na > 0, np > 0) {
                (false, _) => Phase::Idle,
                (true, false) => Phase::Decode,
                (true, true) => {
                    if na == np {
                        Phase::Prompt
                    } else {
                        Phase::Mixed
                    }
                }
            });
        }
        out
    }

    /// Server GPU power (W) for each timestep given the phase labels.
    pub fn power(&self, cat: &Catalog, cfg: &ServerConfig, phases: &[Phase]) -> Vec<f32> {
        let gpu = cat.gpu_of(cfg);
        let inactive = (cfg.n_gpus_server - cfg.tp) as f64 * gpu.idle_w;
        let active_tdp = cfg.tp as f64 * gpu.tdp_w;
        let active_idle = cfg.tp as f64 * gpu.idle_w;
        phases
            .iter()
            .map(|p| {
                let w = match p {
                    Phase::Idle => active_idle,
                    Phase::Prompt => self.ratios.prompt * active_tdp,
                    Phase::Decode => self.ratios.decode * active_tdp,
                    Phase::Mixed => self.ratios.mixed * active_tdp,
                };
                (w + inactive) as f32
            })
            .collect()
    }

    /// Full pipeline: intervals → phases → power.
    pub fn trace(
        &self,
        cat: &Catalog,
        cfg: &ServerConfig,
        intervals: &[ActiveInterval],
        n_steps: usize,
        dt_s: f64,
    ) -> Vec<f32> {
        let phases = Self::phases(intervals, n_steps, dt_s);
        self.power(cat, cfg, &phases)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(start: f64, prefill: f64, decode: f64) -> ActiveInterval {
        ActiveInterval { start_s: start, prefill_s: prefill, decode_s: decode }
    }

    #[test]
    fn phase_labeling_sequence() {
        // One request: prefill [1.0, 1.5), decode [1.5, 3.0).
        let phases = LutBaseline::phases(&[iv(1.0, 0.5, 1.5)], 16, 0.25);
        assert_eq!(phases[0], Phase::Idle);
        assert_eq!(phases[4], Phase::Prompt); // t=1.0
        assert_eq!(phases[7], Phase::Decode); // t=1.75
        assert_eq!(phases[13], Phase::Idle); // after end (bin 12 inclusive)
    }

    #[test]
    fn mixed_when_prefill_overlaps_decode() {
        // Req A decodes while req B prefills at t=2.0.
        let ivs = [iv(0.0, 0.25, 4.0), iv(2.0, 0.5, 1.0)];
        let phases = LutBaseline::phases(&ivs, 20, 0.25);
        assert_eq!(phases[8], Phase::Mixed); // t=2.0: A in decode, B in prefill
    }

    #[cfg(feature = "host")]
    #[test]
    fn power_levels_are_discrete() {
        let cat = Catalog::load_default().unwrap();
        let cfg = cat.config("llama70b_a100_tp8").unwrap();
        let lut = LutBaseline::default();
        let phases = vec![Phase::Idle, Phase::Prompt, Phase::Decode, Phase::Mixed];
        let p = lut.power(&cat, cfg, &phases);
        // TP=8 on A100: idle=440, prompt=0.87*3200, decode=0.5*3200, mixed=0.92*3200
        assert!((p[0] as f64 - 440.0).abs() < 1e-6);
        assert!((p[1] as f64 - 2784.0).abs() < 1e-3);
        assert!((p[2] as f64 - 1600.0).abs() < 1e-3);
        assert!((p[3] as f64 - 2944.0).abs() < 1e-3);
        // Exactly 4 distinct levels ever — the LUT's structural limitation.
        let mut distinct: Vec<f32> = p.clone();
        distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
        distinct.dedup();
        assert_eq!(distinct.len(), 4);
    }

    #[cfg(feature = "host")]
    #[test]
    fn tp_subset_keeps_other_gpus_idle() {
        let cat = Catalog::load_default().unwrap();
        let cfg = cat.config("llama8b_a100_tp2").unwrap();
        let lut = LutBaseline::default();
        let p = lut.power(&cat, cfg, &[Phase::Prompt]);
        // 2 GPUs at 0.87*400 + 6 idle at 55
        let expect = 0.87 * 2.0 * 400.0 + 6.0 * 55.0;
        assert!((p[0] as f64 - expect).abs() < 1e-3);
    }

    #[test]
    fn empty_intervals_is_all_idle() {
        let phases = LutBaseline::phases(&[], 8, 0.25);
        assert!(phases.iter().all(|&p| p == Phase::Idle));
    }
}
