//! Comparison baselines (paper §4.3): TDP nameplate, empirical mean power,
//! and a Splitwise-style phase look-up-table model.

pub mod lut;

pub use lut::{LutBaseline, LutRatios, Phase};

use crate::catalog::{Catalog, ServerConfig};

/// *TDP (nameplate)*: every server draws rated TDP at all times — all 8
/// GPUs at TDP plus the non-GPU IT base (most conservative abstraction).
pub fn tdp_trace(cat: &Catalog, cfg: &ServerConfig, n_steps: usize) -> Vec<f32> {
    let p = cat.server_nameplate_w(cfg) as f32;
    vec![p; n_steps]
}

/// GPU-only TDP level (no IT base), matching how server-level fidelity
/// metrics compare against measured GPU power.
pub fn tdp_gpu_trace(cat: &Catalog, cfg: &ServerConfig, n_steps: usize) -> Vec<f32> {
    let gpu = cat.gpu_of(cfg);
    vec![(gpu.tdp_w * cfg.n_gpus_server as f64) as f32; n_steps]
}

/// *Mean power*: every server draws its empirical training-set mean at all
/// times (`P(t) = ȳ_train`).
pub fn mean_trace(train_mean_w: f64, n_steps: usize) -> Vec<f32> {
    vec![train_mean_w as f32; n_steps]
}

/// Empirical mean of a set of training traces (pooled).
pub fn pooled_mean(traces: &[Vec<f32>]) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for t in traces {
        sum += t.iter().map(|&x| x as f64).sum::<f64>();
        n += t.len();
    }
    assert!(n > 0, "pooled_mean: no samples");
    sum / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "host")]
    #[test]
    fn tdp_is_nameplate_flat() {
        let cat = Catalog::load_default().unwrap();
        let cfg = cat.config("llama70b_a100_tp8").unwrap();
        let t = tdp_trace(&cat, cfg, 10);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|&p| p == 4200.0));
        let g = tdp_gpu_trace(&cat, cfg, 4);
        assert!(g.iter().all(|&p| p == 3200.0));
    }

    #[test]
    fn mean_trace_flat() {
        let t = mean_trace(1234.5, 3);
        assert_eq!(t, vec![1234.5f32; 3]);
    }

    #[test]
    fn pooled_mean_weights_by_length() {
        let a = vec![100.0f32; 10];
        let b = vec![200.0f32; 30];
        let m = pooled_mean(&[a, b]);
        assert!((m - 175.0).abs() < 1e-9);
    }
}
