//! Background artifact refresh: pick up retrained artifacts without
//! restarting the service or cold-starting its caches.
//!
//! A watcher thread fingerprints the artifact store directory every
//! `interval_s` (manifest bytes + sorted file name/length listing — no
//! inotify, no clock on file contents). On change it reopens the store
//! and calls [`Generator::refresh_store`] under the server's write lock:
//! in-flight runs finish on the old prepared configs they hold
//! (`Arc`-shared, so nothing is pulled out from under them), the caches
//! are cleared, and the previously-warm config set is re-prepared from
//! the new bytes before the lock is released — the next request sees
//! fresh artifacts and a warm cache.

use crate::artifacts::ArtifactStore;
use crate::coordinator::Generator;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub struct ArtifactRefresher {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    /// Completed refreshes, for `healthz`.
    refreshes: Arc<AtomicU64>,
}

impl ArtifactRefresher {
    /// Start the watcher. `root` is the store directory the generator
    /// was opened on; `interval_s > 0` (callers gate the zero=off case).
    pub fn start(
        gen: Arc<RwLock<Generator>>,
        root: PathBuf,
        interval_s: f64,
    ) -> ArtifactRefresher {
        let stop = Arc::new(AtomicBool::new(false));
        let refreshes = Arc::new(AtomicU64::new(0));
        let thread_stop = stop.clone();
        let thread_refreshes = refreshes.clone();
        let handle = std::thread::spawn(move || {
            let mut last = fingerprint(&root);
            while !sleep_interval(&thread_stop, interval_s) {
                let now = fingerprint(&root);
                // Unreadable store (mid-rewrite, say): keep the old
                // fingerprint and try again next interval.
                let Some(fp) = now else { continue };
                if last == Some(fp) {
                    continue;
                }
                match reopen(&gen, &root) {
                    Ok(warm) => {
                        thread_refreshes.fetch_add(1, Ordering::Relaxed);
                        last = Some(fp);
                        eprintln!(
                            "serve: artifact store refreshed ({} config(s) re-prepared)",
                            warm.len()
                        );
                    }
                    Err(e) => {
                        // Stay on the old store; retry on the next change.
                        eprintln!("serve: artifact refresh failed: {e:#}");
                    }
                }
            }
        });
        ArtifactRefresher { stop, handle: Some(handle), refreshes }
    }

    pub fn refresh_count(&self) -> u64 {
        self.refreshes.load(Ordering::Relaxed)
    }

    /// Signal and join the watcher (idempotent; also runs on drop).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ArtifactRefresher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn reopen(gen: &RwLock<Generator>, root: &Path) -> Result<Vec<String>> {
    let store = ArtifactStore::open(root)?;
    let mut g = gen.write().unwrap_or_else(|e| e.into_inner());
    g.refresh_store(store)
}

/// Sleep `interval_s` in 100 ms slices; true means stop was requested.
fn sleep_interval(stop: &AtomicBool, interval_s: f64) -> bool {
    let deadline = Instant::now() + Duration::from_secs_f64(interval_s.max(0.1));
    while Instant::now() < deadline {
        if stop.load(Ordering::Relaxed) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    stop.load(Ordering::Relaxed)
}

/// Order-independent content fingerprint of a store directory: FNV-1a
/// over `manifest.json` bytes (which carries per-artifact hashes) plus
/// the sorted (file name, length) listing for anything the manifest
/// doesn't cover. `None` when the directory is unreadable.
fn fingerprint(root: &Path) -> Option<u64> {
    let mut h: u64 = 0xcbf29ce484222325;
    let manifest = std::fs::read(root.join("manifest.json")).ok()?;
    fnv1a(&mut h, &manifest);
    let mut entries: Vec<(String, u64)> = Vec::new();
    for entry in std::fs::read_dir(root).ok()? {
        let entry = entry.ok()?;
        let meta = entry.metadata().ok()?;
        if meta.is_file() {
            entries.push((entry.file_name().to_string_lossy().into_owned(), meta.len()));
        }
    }
    entries.sort();
    for (name, len) in &entries {
        fnv1a(&mut h, name.as_bytes());
        fnv1a(&mut h, &len.to_le_bytes());
    }
    Some(h)
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}
