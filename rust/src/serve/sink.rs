//! The streaming bridge: a [`TraceSink`] whose writes become channel
//! events instead of files.
//!
//! The serve layer's byte-identity contract hangs on this adapter being
//! *transparent*: the engine calls exactly the same `open`/`append`/
//! `close`/`put` sequence it would against a
//! [`DirSink`](crate::export::DirSink), and every call is forwarded as
//! one [`SinkEvent`] carrying the same path and the same bytes. The
//! HTTP handler drains the channel into NDJSON lines; a client that
//! replays the events (accumulate `append`s per path, publish at
//! `close`, take `file` verbatim) reconstructs the DirSink directory
//! byte-for-byte — which is what `rust/tests/serve_integration.rs` pins.
//!
//! A send fails only when the receiver is gone (client disconnected);
//! the error propagates up through the engine and aborts the run — a
//! dropped connection must not keep burning generator time.

use crate::export::{TraceOut, TraceSink};
use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

/// One sink call, reified. `data` is always the exact bytes the engine
/// wrote (CSV/JSON text in practice).
#[derive(Debug, Clone, PartialEq)]
pub enum SinkEvent {
    /// `TraceSink::open(path)` — a streamed file begins.
    Open { path: String },
    /// `TraceOut::append` on an open file.
    Append { path: String, data: Vec<u8> },
    /// `TraceOut::close` — the streamed file is complete and published.
    Close { path: String },
    /// `TraceSink::put` — a complete one-shot file.
    File { path: String, data: Vec<u8> },
}

impl SinkEvent {
    /// The NDJSON wire form (one line per event; `data` fields carry the
    /// engine's text exports, which are UTF-8 by construction).
    pub fn to_json(&self) -> Json {
        match self {
            SinkEvent::Open { path } => json::obj([
                ("event", Json::Str("open".to_string())),
                ("path", Json::Str(path.clone())),
            ]),
            SinkEvent::Append { path, data } => json::obj([
                ("event", Json::Str("append".to_string())),
                ("path", Json::Str(path.clone())),
                ("data", Json::Str(String::from_utf8_lossy(data).into_owned())),
            ]),
            SinkEvent::Close { path } => json::obj([
                ("event", Json::Str("close".to_string())),
                ("path", Json::Str(path.clone())),
            ]),
            SinkEvent::File { path, data } => json::obj([
                ("event", Json::Str("file".to_string())),
                ("path", Json::Str(path.clone())),
                ("data", Json::Str(String::from_utf8_lossy(data).into_owned())),
            ]),
        }
    }
}

/// [`TraceSink`] that forwards every write as a [`SinkEvent`].
///
/// The `Sender` sits behind a `Mutex` only because `TraceSink: Sync`
/// while `mpsc::Sender` is `!Sync`; each streamed file clones its own
/// sender at `open` time, so concurrent facility streams never contend
/// on it mid-window.
pub struct ChannelSink {
    tx: Mutex<Sender<SinkEvent>>,
}

impl ChannelSink {
    pub fn new(tx: Sender<SinkEvent>) -> ChannelSink {
        ChannelSink { tx: Mutex::new(tx) }
    }

    fn send(&self, ev: SinkEvent) -> Result<()> {
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        tx.send(ev).map_err(|_| anyhow!("stream client disconnected"))
    }
}

impl TraceSink for ChannelSink {
    fn open(&self, path: &str) -> Result<Box<dyn TraceOut>> {
        self.send(SinkEvent::Open { path: path.to_string() })?;
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner()).clone();
        Ok(Box::new(ChannelOut { path: path.to_string(), tx }))
    }

    fn put(&self, path: &str, bytes: &[u8]) -> Result<()> {
        self.send(SinkEvent::File { path: path.to_string(), data: bytes.to_vec() })
    }
}

struct ChannelOut {
    path: String,
    tx: Sender<SinkEvent>,
}

impl TraceOut for ChannelOut {
    fn append(&mut self, bytes: &[u8]) -> Result<()> {
        self.tx
            .send(SinkEvent::Append { path: self.path.clone(), data: bytes.to_vec() })
            .map_err(|_| anyhow!("stream client disconnected"))
    }

    fn close(self: Box<Self>) -> Result<()> {
        self.tx
            .send(SinkEvent::Close { path: self.path })
            .map_err(|_| anyhow!("stream client disconnected"))
    }
}

/// Replay a drained event stream into (path → published bytes) — the
/// client-side reconstruction rule, used by tests and documented for API
/// consumers: bytes equal what a [`DirSink`](crate::export::DirSink)
/// run of the same request would have on disk.
pub fn reconstruct(events: &[SinkEvent]) -> std::collections::BTreeMap<String, Vec<u8>> {
    use std::collections::BTreeMap;
    let mut open: BTreeMap<String, Vec<u8>> = BTreeMap::new();
    let mut published: BTreeMap<String, Vec<u8>> = BTreeMap::new();
    for ev in events {
        match ev {
            SinkEvent::Open { path } => {
                open.insert(path.clone(), Vec::new());
            }
            SinkEvent::Append { path, data } => {
                open.entry(path.clone()).or_default().extend_from_slice(data);
            }
            SinkEvent::Close { path } => {
                if let Some(bytes) = open.remove(path) {
                    published.insert(path.clone(), bytes);
                }
            }
            SinkEvent::File { path, data } => {
                published.insert(path.clone(), data.clone());
            }
        }
    }
    published
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::export::MemSink;
    use std::sync::mpsc;

    /// The same scripted write sequence through ChannelSink-reconstruct
    /// and MemSink publishes identical bytes — the transparency contract
    /// in miniature.
    #[test]
    fn channel_events_reconstruct_to_memsink_bytes() {
        let script = |sink: &dyn TraceSink| -> Result<()> {
            let mut a = sink.open("cell/series.csv")?;
            a.append(b"t,w\n")?;
            a.append(b"0,100\n")?;
            a.close()?;
            sink.put("summary.csv", b"id,peak\nc0,42\n")?;
            let b = sink.open("cell/abandoned.csv")?;
            drop(b); // never closed: must not publish
            Ok(())
        };

        let mem = MemSink::new();
        script(&mem).unwrap();

        let (tx, rx) = mpsc::channel();
        let chan = ChannelSink::new(tx);
        script(&chan).unwrap();
        drop(chan);
        let events: Vec<SinkEvent> = rx.iter().collect();
        let files = reconstruct(&events);

        assert_eq!(files.keys().collect::<Vec<_>>(), mem.paths().iter().collect::<Vec<_>>());
        for path in mem.paths() {
            assert_eq!(files[&path], mem.get(&path).unwrap(), "bytes differ at {path}");
        }
        // Event stream shape: open precedes append precedes close.
        assert_eq!(events[0], SinkEvent::Open { path: "cell/series.csv".into() });
        assert!(matches!(events.last(), Some(SinkEvent::Open { .. })));
    }
}
