//! The run registry: ids and lifecycle states for every request the
//! server has accepted, backing `GET /v1/runs/:id` and the `healthz`
//! active-run gauge.
//!
//! Ids are `run-<n>` with a process-lifetime counter — stable, ordered,
//! and meaningless across restarts (durable identity belongs to the
//! manifest machinery, keyed by content hash, not to the service).

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunState {
    Running,
    Done,
    Failed(String),
}

impl RunState {
    pub fn as_str(&self) -> &'static str {
        match self {
            RunState::Running => "running",
            RunState::Done => "done",
            RunState::Failed(_) => "failed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunRecord {
    pub id: String,
    /// Wire kind tag (`facility` | `sweep` | `site` | `site_sweep`).
    pub kind: String,
    /// The spec's human-facing name.
    pub name: String,
    pub state: RunState,
}

#[derive(Default)]
pub struct RunRegistry {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    next: u64,
    runs: BTreeMap<String, RunRecord>,
}

impl RunRegistry {
    pub fn new() -> RunRegistry {
        RunRegistry::default()
    }

    /// Register an accepted request; returns its fresh `run-<n>` id.
    pub fn begin(&self, kind: &str, name: &str) -> String {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let id = format!("run-{}", inner.next);
        inner.next += 1;
        inner.runs.insert(
            id.clone(),
            RunRecord {
                id: id.clone(),
                kind: kind.to_string(),
                name: name.to_string(),
                state: RunState::Running,
            },
        );
        id
    }

    pub fn finish(&self, id: &str) {
        self.set(id, RunState::Done);
    }

    pub fn fail(&self, id: &str, reason: &str) {
        self.set(id, RunState::Failed(reason.to_string()));
    }

    fn set(&self, id: &str, state: RunState) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(rec) = inner.runs.get_mut(id) {
            rec.state = state;
        }
    }

    pub fn get(&self, id: &str) -> Option<RunRecord> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).runs.get(id).cloned()
    }

    /// Requests currently executing.
    pub fn active(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .runs
            .values()
            .filter(|r| r.state == RunState::Running)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_counters() {
        let reg = RunRegistry::new();
        let a = reg.begin("site", "tri");
        let b = reg.begin("sweep", "grid");
        assert_eq!(a, "run-0");
        assert_eq!(b, "run-1");
        assert_eq!(reg.active(), 2);
        reg.finish(&a);
        reg.fail(&b, "boom");
        assert_eq!(reg.active(), 0);
        assert_eq!(reg.get(&a).unwrap().state, RunState::Done);
        assert_eq!(reg.get(&b).unwrap().state, RunState::Failed("boom".to_string()));
        assert!(reg.get("run-99").is_none());
    }
}
