//! Request handlers: the four endpoints, all fronted by the
//! [`RunRequest`](crate::api::RunRequest) envelope.
//!
//! | Endpoint            | Behavior                                          |
//! |---------------------|---------------------------------------------------|
//! | `POST /v1/runs`     | Execute a RunRequest; stream NDJSON sink events   |
//! | `GET /v1/runs/:id`  | Registry state (+ manifest counts when on disk)   |
//! | `GET /healthz`      | Liveness, prepared configs, active runs, refresh  |
//! | `GET /v1/catalog`   | Serving configurations the store can synthesize   |
//!
//! Error discipline: failures before the response head is sent map to
//! HTTP status codes (400 malformed request, 404 unknown run, 500
//! engine error); once a stream is open, failures become a terminal
//! `{"event":"error"}` NDJSON line — the status line is already gone.

use super::sink::ChannelSink;
use super::{http, ServerState};
use crate::api::{self, RunKind, RunRequest};
use crate::robust::{CellStatus, RunManifest};
use crate::util::json::{self, Json};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

pub(crate) fn handle(state: &Arc<ServerState>, mut stream: TcpStream) {
    // A stuck peer must not pin a connection thread forever; runs
    // themselves stream outbound and are not subject to this timeout.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            http::respond_error(&mut stream, 400, &format!("{e:#}"));
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/runs") => post_run(state, &mut stream, &req.body),
        ("GET", "/healthz") => healthz(state, &mut stream),
        ("GET", "/v1/catalog") => catalog(state, &mut stream),
        ("GET", path) if path.strip_prefix("/v1/runs/").is_some() => {
            let id = path.strip_prefix("/v1/runs/").unwrap_or_default();
            run_status(state, &mut stream, id);
        }
        ("POST" | "GET", _) => http::respond_error(&mut stream, 404, "no such endpoint"),
        _ => http::respond_error(&mut stream, 405, "method not allowed"),
    }
}

fn post_run(state: &Arc<ServerState>, stream: &mut TcpStream, body: &[u8]) {
    let parsed = std::str::from_utf8(body)
        .map_err(anyhow::Error::from)
        .and_then(|s| json::parse(s).map_err(anyhow::Error::from))
        .and_then(|v| RunRequest::from_json(&v))
        .and_then(|req| {
            req.spec.validate()?;
            Ok(req)
        });
    let req = match parsed {
        Ok(r) => r,
        Err(e) => {
            http::respond_error(stream, 400, &format!("invalid RunRequest: {e:#}"));
            return;
        }
    };

    // Bound concurrency *before* touching the generator; excess requests
    // queue here on their connection thread.
    let _slot = state.slots.acquire();
    let run_id = state.registry.begin(req.spec.kind().as_str(), &req.spec.name());

    // Warm any configs this request adds, under a short write lock;
    // execution below shares the generator read-locked.
    {
        let mut g = state.gen.write().unwrap_or_else(|e| e.into_inner());
        if let Err(e) = api::prepare(&mut g, &req.spec) {
            state.registry.fail(&run_id, &format!("{e:#}"));
            http::respond_error(stream, 500, &format!("prepare: {e:#}"));
            return;
        }
    }

    let checkpointed = state.runs_dir.is_some()
        && matches!(req.spec.kind(), RunKind::Sweep | RunKind::SiteSweep);
    if checkpointed {
        run_checkpointed(state, stream, &req, &run_id);
    } else {
        run_streamed(state, stream, &req, &run_id);
    }
}

/// The streaming path: engine windows → [`ChannelSink`] events → one
/// NDJSON line each, then a terminal `done`/`error` line.
fn run_streamed(state: &Arc<ServerState>, stream: &mut TcpStream, req: &RunRequest, run_id: &str) {
    let mut out = match http::ChunkedWriter::begin(stream) {
        Ok(w) => w,
        Err(_) => {
            state.registry.fail(run_id, "client disconnected before stream start");
            return;
        }
    };
    let accepted = json::obj([
        ("event", Json::Str("accepted".to_string())),
        ("run_id", Json::Str(run_id.to_string())),
        ("kind", Json::Str(req.spec.kind().as_str().to_string())),
        ("name", Json::Str(req.spec.name())),
    ]);
    let mut client_gone = out.write_line(&json::to_string(&accepted)).is_err();

    let result = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel();
        let sink = ChannelSink::new(tx);
        let worker = scope.spawn(move || {
            let g = state.gen.read().unwrap_or_else(|e| e.into_inner());
            api::execute_prepared(&g, req, Some(&sink))
        });
        // Drain until the worker drops the sink (its only sender). A
        // write failure means the client went away: stop draining and
        // drop the receiver, so the sink's next send errors and aborts
        // the engine — a dead connection must not burn generator time.
        if !client_gone {
            for ev in rx.iter() {
                if out.write_line(&json::to_string(&ev.to_json())).is_err() {
                    client_gone = true;
                    break;
                }
            }
        }
        drop(rx);
        worker.join()
    });

    let terminal = match result {
        Ok(Ok(_outcome)) => {
            state.registry.finish(run_id);
            json::obj([
                ("event", Json::Str("done".to_string())),
                ("run_id", Json::Str(run_id.to_string())),
            ])
        }
        Ok(Err(e)) => {
            state.registry.fail(run_id, &format!("{e:#}"));
            json::obj([
                ("event", Json::Str("error".to_string())),
                ("run_id", Json::Str(run_id.to_string())),
                ("message", Json::Str(format!("{e:#}"))),
            ])
        }
        Err(_) => {
            state.registry.fail(run_id, "run worker panicked");
            json::obj([
                ("event", Json::Str("error".to_string())),
                ("run_id", Json::Str(run_id.to_string())),
                ("message", Json::Str("run worker panicked".to_string())),
            ])
        }
    };
    if !client_gone {
        let _ = out.write_line(&json::to_string(&terminal));
        let _ = out.finish();
    }
}

/// The durable path (`--runs-dir` + a sweep kind): checkpointed
/// execution into `<runs_dir>/<run-id>/` — crash-safe manifest, atomic
/// exports, `--resume`-able from the CLI — with the summary returned in
/// one JSON body rather than streamed.
fn run_checkpointed(
    state: &Arc<ServerState>,
    stream: &mut TcpStream,
    req: &RunRequest,
    run_id: &str,
) {
    let dir = state.runs_dir.as_ref().expect("checkpointed implies runs_dir").join(run_id);
    let result = {
        let g = state.gen.read().unwrap_or_else(|e| e.into_inner());
        api::execute_checkpointed_prepared(&g, req, &dir)
    };
    match result {
        Ok(outcome) => {
            if outcome.failed().is_empty() {
                state.registry.finish(run_id);
            } else {
                state.registry.fail(
                    run_id,
                    &format!("{} cell(s) quarantined", outcome.failed().len()),
                );
            }
            let body = json::obj([
                ("run_id", Json::Str(run_id.to_string())),
                ("dir", Json::Str(dir.display().to_string())),
                ("restored", Json::Num(outcome.restored() as f64)),
                ("failed", Json::Num(outcome.failed().len() as f64)),
                ("interrupted", Json::Num(outcome.interrupted() as f64)),
                ("summary_csv", Json::Str(outcome.summary_csv().to_string())),
            ]);
            let _ = http::respond_json(stream, 200, &body);
        }
        Err(e) => {
            state.registry.fail(run_id, &format!("{e:#}"));
            http::respond_error(stream, 500, &format!("{e:#}"));
        }
    }
}

fn run_status(state: &Arc<ServerState>, stream: &mut TcpStream, id: &str) {
    let Some(rec) = state.registry.get(id) else {
        http::respond_error(stream, 404, &format!("unknown run '{id}'"));
        return;
    };
    let mut fields = vec![
        ("run_id", Json::Str(rec.id.clone())),
        ("kind", Json::Str(rec.kind.clone())),
        ("name", Json::Str(rec.name.clone())),
        ("state", Json::Str(rec.state.as_str().to_string())),
    ];
    if let super::registry::RunState::Failed(reason) = &rec.state {
        fields.push(("error", Json::Str(reason.clone())));
    }
    // Durable runs carry a PR-7 manifest: fold its cell ledger in.
    if let Some(runs_dir) = &state.runs_dir {
        let mpath = runs_dir.join(id).join("manifest.json");
        if mpath.exists() {
            match RunManifest::load(&mpath) {
                Ok(m) => {
                    let count = |s: CellStatus| {
                        m.cells.values().filter(|c| c.status == s).count() as f64
                    };
                    fields.push((
                        "manifest",
                        json::obj([
                            ("path", Json::Str(mpath.display().to_string())),
                            ("grid_hash", Json::Str(m.grid_hash.clone())),
                            ("done", Json::Num(count(CellStatus::Done))),
                            ("failed", Json::Num(count(CellStatus::Failed))),
                            ("pending", Json::Num(count(CellStatus::Pending))),
                        ]),
                    ));
                }
                Err(e) => fields.push(("manifest_error", Json::Str(format!("{e:#}")))),
            }
        }
    }
    let _ = http::respond_json(stream, 200, &json::obj(fields));
}

fn healthz(state: &Arc<ServerState>, stream: &mut TcpStream) {
    let (prepared, store_root) = {
        let g = state.gen.read().unwrap_or_else(|e| e.into_inner());
        (g.prepared_ids(), g.store.root.display().to_string())
    };
    let refresh = match &state.refresh_count {
        Some(r) => json::obj([
            ("interval_s", Json::Num(state.refresh_interval_s)),
            ("count", Json::Num(r.refresh_count() as f64)),
        ]),
        None => Json::Null,
    };
    let body = json::obj([
        ("status", Json::Str("ok".to_string())),
        ("store_root", Json::Str(store_root)),
        (
            "prepared_configs",
            Json::Arr(prepared.into_iter().map(Json::Str).collect()),
        ),
        ("active_runs", Json::Num(state.registry.active() as f64)),
        ("refresh", refresh),
    ]);
    let _ = http::respond_json(stream, 200, &body);
}

fn catalog(state: &Arc<ServerState>, stream: &mut TcpStream) {
    let g = state.gen.read().unwrap_or_else(|e| e.into_inner());
    let configs: Vec<Json> = g
        .cat
        .configs
        .iter()
        .map(|c| {
            json::obj([
                ("id", Json::Str(c.id.clone())),
                ("model", Json::Str(c.model.clone())),
                ("gpu", Json::Str(c.gpu.clone())),
                ("tp", Json::Num(c.tp as f64)),
                ("n_gpus_server", Json::Num(c.n_gpus_server as f64)),
            ])
        })
        .collect();
    let datasets: Vec<Json> = g.cat.datasets.keys().cloned().map(Json::Str).collect();
    let prepared: Vec<Json> = g.prepared_ids().into_iter().map(Json::Str).collect();
    drop(g);
    let body = json::obj([
        ("configs", Json::Arr(configs)),
        ("datasets", Json::Arr(datasets)),
        ("prepared", Json::Arr(prepared)),
    ]);
    let _ = http::respond_json(stream, 200, &body);
}
