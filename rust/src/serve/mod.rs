//! The live planning service: `powertrace serve`.
//!
//! Batch studies cold-start artifact loading, classifier construction,
//! and weight packing on every CLI invocation. This module keeps all of
//! that warm in one long-running process and exposes the engine over
//! HTTP, so many concurrent planning studies amortize one prepared-config
//! cache (the ROADMAP's "Live-traffic service mode"):
//!
//! * **One API.** The request body of `POST /v1/runs` is exactly the
//!   [`RunRequest`](crate::api::RunRequest) JSON envelope — the same
//!   `{"kind", "spec", "options"}` shape the library and CLI use, over
//!   the unchanged scenario/grid/site file schemas. Nothing is served
//!   that cannot also be run in batch.
//! * **Streaming, not polling.** Runs stream back incrementally as
//!   NDJSON: one line per [`SinkEvent`](sink::SinkEvent) as the engine's
//!   windows pass through a [`ChannelSink`](sink::ChannelSink), then a
//!   terminal `done`/`error` line. Replaying the events reconstructs the
//!   byte-identical [`DirSink`](crate::export::DirSink) directory of the
//!   same request (pinned by `rust/tests/serve_integration.rs`).
//! * **Shared warm generator.** One [`Generator`] behind an `RwLock`:
//!   requests prepare missing configs under a short write lock, then
//!   execute concurrently under read locks
//!   ([`execute_prepared`](crate::api::execute_prepared) takes
//!   `&Generator`). A [`ArtifactRefresher`](refresh::ArtifactRefresher)
//!   swaps in retrained artifacts between runs and re-warms the cache.
//! * **Bounded.** A counting semaphore caps concurrent runs
//!   (`--max-runs`); excess requests queue on accept threads. SIGINT /
//!   SIGTERM drain through [`crate::robust::shutdown`], so a served
//!   checkpointed run leaves a consistent resumable manifest.
//!
//! Endpoints: `POST /v1/runs`, `GET /v1/runs/:id`, `GET /healthz`,
//! `GET /v1/catalog` — see README §"Planning service" for the table and
//! curl examples, and `docs/ARCHITECTURE.md` §"Service mode" for the
//! design.
//!
//! Everything here is behind the `serve` cargo feature (implies `host`);
//! the core engine stays I/O-free.

pub mod http;
pub mod refresh;
pub mod registry;
mod routes;
pub mod sink;

use crate::coordinator::Generator;
use anyhow::{Context, Result};
use refresh::ArtifactRefresher;
use registry::RunRegistry;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Service configuration (CLI flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8791`. Port 0 picks a free port
    /// (tests); [`Server::local_addr`] reports the resolved one.
    pub addr: String,
    /// Concurrent-run cap; further requests queue.
    pub max_concurrent_runs: usize,
    /// When set, `sweep`/`site_sweep` requests execute *checkpointed*
    /// into `<runs_dir>/<run-id>/` — durable manifest + exports on disk,
    /// summary over the wire — and `GET /v1/runs/:id` folds the manifest
    /// into the status body. When unset those kinds stream like the rest.
    pub runs_dir: Option<PathBuf>,
    /// Artifact-store re-check cadence; 0 disables the refresher.
    pub refresh_interval_s: f64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8791".to_string(),
            max_concurrent_runs: 2,
            runs_dir: None,
            refresh_interval_s: 0.0,
        }
    }
}

/// Everything a connection handler needs, shared across threads.
pub(crate) struct ServerState {
    pub gen: Arc<RwLock<Generator>>,
    pub registry: RunRegistry,
    pub slots: Semaphore,
    pub runs_dir: Option<PathBuf>,
    pub refresh_interval_s: f64,
    /// Present iff the refresher is running.
    pub refresh_count: Option<Arc<ArtifactRefresher>>,
}

pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    refresher: Option<Arc<ArtifactRefresher>>,
}

impl Server {
    /// Bind the listener and start the refresher (if configured). The
    /// generator should arrive warm (configs prepared) for best first-hit
    /// latency, but any missing config is prepared on demand per request.
    pub fn new(gen: Generator, cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let store_root = gen.store.root.clone();
        let gen = Arc::new(RwLock::new(gen));
        let refresher = if cfg.refresh_interval_s > 0.0 {
            Some(Arc::new(ArtifactRefresher::start(
                gen.clone(),
                store_root,
                cfg.refresh_interval_s,
            )))
        } else {
            None
        };
        if let Some(dir) = &cfg.runs_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating runs dir {}", dir.display()))?;
        }
        let state = Arc::new(ServerState {
            gen,
            registry: RunRegistry::new(),
            slots: Semaphore::new(cfg.max_concurrent_runs.max(1)),
            runs_dir: cfg.runs_dir.clone(),
            refresh_interval_s: cfg.refresh_interval_s,
            refresh_count: refresher.clone(),
        });
        Ok(Server { listener, state, refresher })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop: one thread per connection, polled non-blocking so the
    /// `stop` flag and [`crate::robust::shutdown`] drain promptly. Blocks
    /// until stopped; connection threads are joined on the way out.
    pub fn run(mut self, stop: Arc<AtomicBool>) -> Result<()> {
        self.listener.set_nonblocking(true).context("nonblocking listener")?;
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) && !crate::robust::shutdown::requested() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let state = self.state.clone();
                    conns.push(std::thread::spawn(move || {
                        routes::handle(&state, stream);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
            conns.retain(|h| !h.is_finished());
        }
        // Drain: in-flight requests finish (their manifests flush), new
        // connections are no longer accepted.
        for h in conns {
            let _ = h.join();
        }
        // The refresher stops via Drop once the last Arc (ours here,
        // plus the one inside `state`) goes away as `self` is consumed.
        drop(self.refresher.take());
        Ok(())
    }

    /// Run on a background thread; the handle stops + joins on demand
    /// (and on drop). The in-process harness tests use this.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let handle = std::thread::spawn(move || self.run(thread_stop));
        Ok(ServerHandle { addr, stop, handle: Some(handle) })
    }
}

pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and join it (≤ one poll interval + the
    /// longest in-flight request).
    pub fn stop(mut self) -> Result<()> {
        self.shutdown()
    }

    fn shutdown(&mut self) -> Result<()> {
        self.stop.store(true, Ordering::Relaxed);
        match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// A counting semaphore (std has none): `acquire` blocks while all
/// permits are out; the guard releases on drop, including on panic.
pub(crate) struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(n: usize) -> Semaphore {
        Semaphore { permits: Mutex::new(n), cv: Condvar::new() }
    }

    pub fn acquire(&self) -> SlotGuard<'_> {
        let mut permits = self.permits.lock().unwrap_or_else(|e| e.into_inner());
        while *permits == 0 {
            permits = self.cv.wait(permits).unwrap_or_else(|e| e.into_inner());
        }
        *permits -= 1;
        SlotGuard { sem: self }
    }
}

pub(crate) struct SlotGuard<'a> {
    sem: &'a Semaphore,
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        let mut permits = self.sem.permits.lock().unwrap_or_else(|e| e.into_inner());
        *permits += 1;
        self.sem.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn semaphore_bounds_concurrency() {
        use std::sync::atomic::AtomicUsize;
        let sem = Arc::new(Semaphore::new(2));
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (sem, live, peak) = (sem.clone(), live.clone(), peak.clone());
            handles.push(std::thread::spawn(move || {
                let _slot = sem.acquire();
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(10));
                live.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }
}
