//! A deliberately minimal HTTP/1.1 layer: exactly what the planning
//! service needs and nothing more.
//!
//! Scope: request-line + headers + `Content-Length` bodies in;
//! fixed-length JSON responses and chunked NDJSON streams out; one
//! request per connection (`Connection: close`). No keep-alive, no
//! `Transfer-Encoding` request bodies, no TLS — the service fronts a
//! trusted planning network, and the no-new-dependencies rule (see
//! Cargo.toml) prices a real HTTP stack out. Caps: 64 KiB of headers,
//! 16 MiB of body.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Header section cap — a request line plus a handful of headers.
const MAX_HEAD: usize = 64 * 1024;
/// Body cap — a site-sweep grid JSON is a few KiB; 16 MiB is generous.
const MAX_BODY: usize = 16 * 1024 * 1024;

/// One parsed request. `path` excludes any query string.
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Read and parse one request from the stream (which the caller has set
/// blocking, with a read timeout).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    // Accumulate until the blank line that ends the header section.
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            bail!("request header section exceeds {MAX_HEAD} bytes");
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).context("reading request")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (Some(method), Some(target)) = (parts.next(), parts.next()) else {
        bail!("malformed request line '{request_line}'");
    };
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        if name == "content-length" {
            content_length = value.parse().with_context(|| format!("content-length '{value}'"))?;
        } else if name == "transfer-encoding" {
            bail!("transfer-encoding request bodies are not supported");
        }
    }
    if content_length > MAX_BODY {
        bail!("request body exceeds {MAX_BODY} bytes");
    }

    // The body: whatever followed the blank line, topped up to length.
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let mut chunk = [0u8; 16 * 1024];
        let n = stream.read(&mut chunk).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method: method.to_string(), path, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response with a JSON body.
pub fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    body: &crate::util::json::Json,
) -> Result<()> {
    let text = crate::util::json::to_string_pretty(body);
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        reason(status),
        text.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Error response: `{"error": msg}`. Write failures are swallowed — the
/// peer may already be gone, and there is nobody left to tell.
pub fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) {
    let body = crate::util::json::obj([(
        "error",
        crate::util::json::Json::Str(msg.to_string()),
    )]);
    let _ = respond_json(stream, status, &body);
}

/// An incremental `Transfer-Encoding: chunked` NDJSON response: one
/// chunk per line, flushed per line so windows reach the client as the
/// engine emits them.
pub struct ChunkedWriter<'a> {
    stream: &'a mut TcpStream,
    finished: bool,
}

impl<'a> ChunkedWriter<'a> {
    /// Send the response head; the body follows via [`Self::write_line`].
    pub fn begin(stream: &'a mut TcpStream) -> Result<ChunkedWriter<'a>> {
        stream.write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
              Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        )?;
        Ok(ChunkedWriter { stream, finished: false })
    }

    /// One NDJSON line (newline appended here), as one chunk.
    pub fn write_line(&mut self, line: &str) -> Result<()> {
        let payload = format!("{line}\n");
        let chunk = format!("{:x}\r\n", payload.len());
        self.stream.write_all(chunk.as_bytes())?;
        self.stream.write_all(payload.as_bytes())?;
        self.stream.write_all(b"\r\n")?;
        self.stream.flush()?;
        Ok(())
    }

    /// Terminal zero-length chunk.
    pub fn finish(mut self) -> Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for ChunkedWriter<'_> {
    fn drop(&mut self) {
        if !self.finished {
            // Best-effort terminator so a panicking handler still leaves
            // the client a well-formed (if truncated) stream.
            let _ = self.stream.write_all(b"0\r\n\r\n");
            let _ = self.stream.flush();
        }
    }
}
