//! `ArtifactSource` — the bytes-in seam of the core/host split.
//!
//! Everything the engine *reads* (trained artifacts, config JSON, replay
//! traces) arrives through this trait, so the pure core never touches
//! `std::fs`. The host shell provides [`FsSource`] (a directory on disk);
//! embedders — wasm, services, tests — provide [`MemSource`] or their own
//! impl over whatever byte store they have.
//!
//! Paths are logical, `/`-separated, and relative to the source root
//! (e.g. `configs/llama8b_a100_tp2.json`). [`FsSource`] maps them onto
//! its root directory; an absolute logical path passes through unchanged
//! (`PathBuf::join` semantics), which is how replay-trace paths recorded
//! in scenario specs keep their current meaning.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Byte provider for everything the engine reads.
pub trait ArtifactSource: Send + Sync {
    /// Read the full contents of a logical path.
    fn read(&self, path: &str) -> Result<Vec<u8>>;
    /// List the entries of a logical directory (file names only, not
    /// full paths), in an implementation-defined order — callers sort.
    fn list(&self, dir: &str) -> Result<Vec<String>>;
}

/// Read a logical path as UTF-8 text.
pub fn read_to_string(src: &dyn ArtifactSource, path: &str) -> Result<String> {
    let bytes = src.read(path)?;
    String::from_utf8(bytes).with_context(|| format!("{path}: not valid UTF-8"))
}

/// In-memory [`ArtifactSource`]: a map of logical path → bytes. The
/// wasm/embedding entry point ("bytes in"), and the test double.
#[derive(Debug, Default)]
pub struct MemSource {
    files: Mutex<BTreeMap<String, Vec<u8>>>,
}

impl MemSource {
    pub fn new() -> MemSource {
        MemSource::default()
    }

    /// Insert (or replace) one logical file.
    pub fn insert(&self, path: &str, bytes: Vec<u8>) {
        self.files.lock().unwrap().insert(path.to_string(), bytes);
    }

    pub fn contains(&self, path: &str) -> bool {
        self.files.lock().unwrap().contains_key(path)
    }

    pub fn len(&self) -> usize {
        self.files.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.lock().unwrap().is_empty()
    }
}

impl ArtifactSource for MemSource {
    fn read(&self, path: &str) -> Result<Vec<u8>> {
        match self.files.lock().unwrap().get(path) {
            Some(b) => Ok(b.clone()),
            None => bail!("{path}: not present in the in-memory artifact source"),
        }
    }

    fn list(&self, dir: &str) -> Result<Vec<String>> {
        let prefix = if dir.is_empty() || dir.ends_with('/') {
            dir.to_string()
        } else {
            format!("{dir}/")
        };
        let files = self.files.lock().unwrap();
        let mut out = Vec::new();
        let mut dir_exists = false;
        for key in files.keys() {
            if let Some(rest) = key.strip_prefix(&prefix) {
                dir_exists = true;
                // Direct children only, mirroring a one-level read_dir.
                if !rest.is_empty() && !rest.contains('/') {
                    out.push(rest.to_string());
                }
            }
        }
        // A directory exists only by virtue of holding files; a prefix no
        // key matches is "not found", like read_dir on a missing path.
        if !dir_exists {
            bail!("{dir}: no such directory in the in-memory artifact source");
        }
        Ok(out)
    }
}

/// Filesystem-backed [`ArtifactSource`] rooted at a directory. With an
/// empty root, logical paths resolve exactly as OS paths (relative to the
/// process cwd, absolute passes through) — the pre-split behaviour of
/// replay-trace loading.
#[cfg(feature = "host")]
#[derive(Debug, Clone)]
pub struct FsSource {
    root: std::path::PathBuf,
}

#[cfg(feature = "host")]
impl FsSource {
    pub fn new(root: impl Into<std::path::PathBuf>) -> FsSource {
        FsSource { root: root.into() }
    }

    /// Passthrough source: logical paths ARE OS paths.
    pub fn passthrough() -> FsSource {
        FsSource { root: std::path::PathBuf::new() }
    }

    fn resolve(&self, path: &str) -> std::path::PathBuf {
        // `join` with an absolute path replaces the root — deliberate:
        // absolute replay paths in specs keep meaning the file they name.
        self.root.join(path)
    }
}

#[cfg(feature = "host")]
impl ArtifactSource for FsSource {
    fn read(&self, path: &str) -> Result<Vec<u8>> {
        let p = self.resolve(path);
        std::fs::read(&p).with_context(|| format!("reading {}", p.display()))
    }

    fn list(&self, dir: &str) -> Result<Vec<String>> {
        let p = self.resolve(dir);
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&p).with_context(|| format!("listing {}", p.display()))?
        {
            let entry = entry?;
            if let Some(name) = entry.file_name().to_str() {
                out.push(name.to_string());
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_source_read_and_list() {
        let src = MemSource::new();
        src.insert("configs/a.json", b"{}".to_vec());
        src.insert("configs/b.json", b"{}".to_vec());
        src.insert("configs/sub/c.json", b"{}".to_vec());
        src.insert("manifest.json", b"{}".to_vec());
        assert_eq!(src.read("manifest.json").unwrap(), b"{}");
        assert!(src.read("missing.json").is_err());
        let mut names = src.list("configs").unwrap();
        names.sort();
        // One level only: sub/c.json is not a direct child of configs/.
        assert_eq!(names, vec!["a.json", "b.json"]);
        let root: Vec<String> = src.list("").unwrap();
        assert_eq!(root, vec!["manifest.json"]);
        assert!(src.list("missing_dir").is_err());
    }

    #[cfg(feature = "host")]
    #[test]
    fn fs_source_reads_relative_to_root() {
        let dir = std::env::temp_dir().join("powertrace_test_fs_source");
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("sub/x.txt"), b"hello").unwrap();
        let src = FsSource::new(&dir);
        assert_eq!(src.read("sub/x.txt").unwrap(), b"hello");
        assert_eq!(src.list("sub").unwrap(), vec!["x.txt"]);
        // Passthrough: an absolute logical path names the OS file.
        let pass = FsSource::passthrough();
        let abs = dir.join("sub/x.txt");
        assert_eq!(pass.read(abs.to_str().unwrap()).unwrap(), b"hello");
    }
}
