//! Two-sample Kolmogorov–Smirnov statistic: the maximum absolute difference
//! between the empirical CDFs of measured and synthetic power samples
//! (paper §4.1: "KS statistic measures whether distributionally our measured
//! and synthetic power samples match").

/// D = sup_x |F_a(x) - F_b(x)| over the pooled support. O(n log n).
pub fn ks_statistic(a: &[f32], b: &[f32]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty(), "ks_statistic: empty sample");
    let mut sa: Vec<f32> = a.to_vec();
    let mut sb: Vec<f32> = b.to_vec();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (na, nb) = (sa.len() as f64, sb.len() as f64);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d: f64 = 0.0;
    while i < sa.len() && j < sb.len() {
        let x = sa[i].min(sb[j]);
        while i < sa.len() && sa[i] <= x {
            i += 1;
        }
        while j < sb.len() && sb[j] <= x {
            j += 1;
        }
        let diff = (i as f64 / na - j as f64 / nb).abs();
        if diff > d {
            d = diff;
        }
    }
    d
}

/// Empirical CDF evaluated at `points` (for Fig 7-style CDF exports).
pub fn ecdf(sample: &[f32], points: &[f32]) -> Vec<f64> {
    let mut s: Vec<f32> = sample.to_vec();
    s.sort_by(|x, y| x.partial_cmp(y).unwrap());
    points
        .iter()
        .map(|&p| {
            let idx = s.partition_point(|&x| x <= p);
            idx as f64 / s.len() as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_samples_have_zero_d() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        assert!(ks_statistic(&a, &a) < 1e-12);
    }

    #[test]
    fn disjoint_samples_have_d_one() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 11.0];
        assert!((ks_statistic(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn symmetric() {
        let a = [1.0f32, 5.0, 2.0, 8.0];
        let b = [3.0f32, 4.0, 9.0];
        assert!((ks_statistic(&a, &b) - ks_statistic(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn known_value_half_shifted() {
        // a = {0,1}, b = {1,2}: CDFs differ by 0.5 on (0,1)∪(1,2).
        let a = [0.0f32, 1.0];
        let b = [1.0f32, 2.0];
        assert!((ks_statistic(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn same_distribution_small_d() {
        let mut r = Rng::new(11);
        let a: Vec<f32> = (0..5000).map(|_| r.normal_ms(300.0, 20.0) as f32).collect();
        let b: Vec<f32> = (0..5000).map(|_| r.normal_ms(300.0, 20.0) as f32).collect();
        assert!(ks_statistic(&a, &b) < 0.05);
        let c: Vec<f32> = (0..5000).map(|_| r.normal_ms(350.0, 20.0) as f32).collect();
        assert!(ks_statistic(&a, &c) > 0.5);
    }

    #[test]
    fn handles_ties() {
        let a = [1.0f32, 1.0, 1.0, 2.0];
        let b = [1.0f32, 2.0, 2.0, 2.0];
        let d = ks_statistic(&a, &b);
        // F_a(1)=0.75, F_b(1)=0.25 → D=0.5
        assert!((d - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_values() {
        let s = [1.0f32, 2.0, 3.0, 4.0];
        let c = ecdf(&s, &[0.5, 1.0, 2.5, 4.0, 9.0]);
        assert_eq!(c, vec![0.0, 0.25, 0.5, 1.0, 1.0]);
    }
}
