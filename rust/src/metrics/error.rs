//! Pointwise and integral error metrics (paper §4.1).

/// NRMSE: root-mean-square pointwise error normalized by the observed
/// (measured) power range. Series must be time-aligned and equal length.
pub fn nrmse(measured: &[f32], synthetic: &[f32]) -> f64 {
    assert_eq!(measured.len(), synthetic.len(), "nrmse: length mismatch");
    assert!(!measured.is_empty(), "nrmse: empty");
    let n = measured.len() as f64;
    let mse: f64 = measured
        .iter()
        .zip(synthetic.iter())
        .map(|(&m, &s)| {
            let d = m as f64 - s as f64;
            d * d
        })
        .sum::<f64>()
        / n;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &m in measured {
        lo = lo.min(m as f64);
        hi = hi.max(m as f64);
    }
    let range = hi - lo;
    if range <= 1e-12 {
        return if mse.sqrt() <= 1e-12 { 0.0 } else { f64::INFINITY };
    }
    mse.sqrt() / range
}

/// Signed relative energy error ΔE = (E_syn − E_meas) / E_meas over the
/// whole trace. With uniform sampling, energies reduce to sample sums.
pub fn delta_energy(measured: &[f32], synthetic: &[f32]) -> f64 {
    assert!(!measured.is_empty() && !synthetic.is_empty(), "delta_energy: empty");
    let e_meas: f64 = measured.iter().map(|&x| x as f64).sum();
    let e_syn: f64 = synthetic.iter().map(|&x| x as f64).sum::<f64>()
        * (measured.len() as f64 / synthetic.len() as f64);
    assert!(e_meas.abs() > 1e-12, "delta_energy: zero measured energy");
    (e_syn - e_meas) / e_meas
}

/// Trace energy in watt-hours given the sampling interval.
pub fn energy_wh(power_w: &[f32], dt_s: f64) -> f64 {
    power_w.iter().map(|&p| p as f64).sum::<f64>() * dt_s / 3600.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nrmse_zero_for_identical() {
        let xs = [100.0f32, 200.0, 150.0];
        assert_eq!(nrmse(&xs, &xs), 0.0);
    }

    #[test]
    fn nrmse_known_value() {
        // measured range 100, constant offset 10 → NRMSE = 0.1
        let m = [100.0f32, 200.0];
        let s = [110.0f32, 210.0];
        assert!((nrmse(&m, &s) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn nrmse_constant_measured() {
        let m = [5.0f32; 4];
        assert_eq!(nrmse(&m, &m), 0.0);
        assert!(nrmse(&m, &[6.0f32; 4]).is_infinite());
    }

    #[test]
    fn delta_energy_signed() {
        let m = [100.0f32; 10];
        let hi = [110.0f32; 10];
        let lo = [90.0f32; 10];
        assert!((delta_energy(&m, &hi) - 0.1).abs() < 1e-12);
        assert!((delta_energy(&m, &lo) + 0.1).abs() < 1e-12);
        assert_eq!(delta_energy(&m, &m), 0.0);
    }

    #[test]
    fn delta_energy_rescales_lengths() {
        // Synthetic twice as long at the same level → same mean power.
        let m = [100.0f32; 10];
        let s = [100.0f32; 20];
        assert!(delta_energy(&m, &s).abs() < 1e-12);
    }

    #[test]
    fn energy_wh_known() {
        // 1000 W for 3600 samples of 1 s = 1 kWh.
        let p = vec![1000.0f32; 3600];
        assert!((energy_wh(&p, 1.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn nrmse_rejects_length_mismatch() {
        nrmse(&[1.0], &[1.0, 2.0]);
    }
}
