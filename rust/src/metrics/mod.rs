//! Trace-fidelity and planning metrics (paper §4.1 "Metrics" and Table 3).
//!
//! - [`ks`] — Kolmogorov–Smirnov statistic between marginal power samples.
//! - [`acf`] — autocorrelation functions and the ACF R² agreement score.
//! - [`error`] — NRMSE and signed relative energy error ΔE.
//! - [`planning`] — peak / average / peak-to-average ratio / ramp rates /
//!   load factor / coefficient of variation / percentiles.

pub mod acf;
pub mod error;
pub mod ks;
pub mod planning;

pub use acf::{acf, acf_r2};
pub use error::{delta_energy, nrmse};
pub use ks::ks_statistic;
pub use planning::{
    clamp_ramp_interval, coefficient_of_variation, max_ramp, peak_to_average, percentile,
    resample_mean, resample_mean_with_tail, resample_stride, PlanningStats, RampStats,
    StreamedStats, StreamingHistogram, StreamingPlanningStats, StreamingRamps,
    StreamingResampler, EXACT_QUANTILE_CAP, QUANTILE_BINS,
};

/// Summary of the paper's four fidelity metrics for one (measured, synthetic)
/// trace pair (Table 1 / Table 2 row fragments).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fidelity {
    pub ks: f64,
    /// `None` for constant baselines (TDP/mean) where ACF is undefined —
    /// rendered as "–" in tables, as the paper does.
    pub acf_r2: Option<f64>,
    pub nrmse: f64,
    /// Signed relative energy error.
    pub delta_energy: f64,
}

/// Compute all four fidelity metrics for a trace pair sampled at `dt_s`.
/// `max_lag` bounds the ACF comparison (the paper preserves sub-minute
/// temporal structure; we use 240 lags = 60 s at 250 ms).
pub fn fidelity(measured: &[f32], synthetic: &[f32], max_lag: usize) -> Fidelity {
    Fidelity {
        ks: ks_statistic(measured, synthetic),
        acf_r2: acf_r2(measured, synthetic, max_lag),
        nrmse: nrmse(measured, synthetic),
        delta_energy: delta_energy(measured, synthetic),
    }
}

/// Median of a slice (interpolated for even lengths). Used for the paper's
/// "median over 5 seeds" reporting rule.
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean and (population) standard deviation — used for Table 1's "a ± b".
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fidelity_perfect_match() {
        let xs: Vec<f32> = (0..500).map(|i| ((i as f32) * 0.1).sin() * 50.0 + 200.0).collect();
        let f = fidelity(&xs, &xs, 50);
        assert!(f.ks < 1e-9);
        assert!((f.acf_r2.unwrap() - 1.0).abs() < 1e-9);
        assert!(f.nrmse < 1e-9);
        assert!(f.delta_energy.abs() < 1e-9);
    }
}
