//! Autocorrelation structure metrics.
//!
//! The paper's `ACF R²` "measures agreement between the autocorrelation
//! function of measured and synthetic traces" (§4.1). We compute each
//! trace's normalized ACF up to `max_lag` and report the coefficient of
//! determination of the synthetic ACF against the measured ACF.

/// Normalized autocorrelation function ρ(0..=max_lag) of `xs`.
/// ρ(0) = 1 by construction; a constant series yields NaN-free zeros for
/// all positive lags by convention (variance guard).
pub fn acf(xs: &[f32], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    assert!(n > 1, "acf: need at least 2 samples");
    let max_lag = max_lag.min(n - 1);
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let c0: f64 = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    let mut out = Vec::with_capacity(max_lag + 1);
    out.push(1.0);
    if c0 <= 1e-12 {
        out.extend(std::iter::repeat(0.0).take(max_lag));
        return out;
    }
    for lag in 1..=max_lag {
        let mut c = 0.0;
        for t in 0..n - lag {
            c += (xs[t] as f64 - mean) * (xs[t + lag] as f64 - mean);
        }
        out.push(c / n as f64 / c0);
    }
    out
}

/// R² of the synthetic ACF against the measured ACF over lags 1..=max_lag
/// (lag 0 is identically 1 for both and excluded).
///
/// Returns `None` when the measured trace is constant (ACF undefined),
/// matching the paper's "–" entries for constant baselines.
pub fn acf_r2(measured: &[f32], synthetic: &[f32], max_lag: usize) -> Option<f64> {
    let var = |xs: &[f32]| {
        let n = xs.len() as f64;
        let m = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
        xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n
    };
    if var(synthetic) <= 1e-12 || var(measured) <= 1e-12 {
        return None;
    }
    let a = acf(measured, max_lag);
    let b = acf(synthetic, max_lag);
    let lags = a.len().min(b.len());
    if lags <= 1 {
        return None;
    }
    let a = &a[1..lags];
    let b = &b[1..lags];
    let mean_a = a.iter().sum::<f64>() / a.len() as f64;
    let ss_tot: f64 = a.iter().map(|x| (x - mean_a).powi(2)).sum();
    let ss_res: f64 = a.iter().zip(b.iter()).map(|(x, y)| (x - y).powi(2)).sum();
    if ss_tot <= 1e-12 {
        // Measured ACF flat (white noise): score by residual magnitude.
        return Some(if ss_res / a.len() as f64 <= 1e-4 { 1.0 } else { 0.0 });
    }
    Some(1.0 - ss_res / ss_tot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lag0_is_one() {
        let xs = [1.0f32, 3.0, 2.0, 5.0, 4.0];
        let a = acf(&xs, 3);
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn white_noise_acf_near_zero() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal() as f32).collect();
        let a = acf(&xs, 10);
        for lag in 1..=10 {
            assert!(a[lag].abs() < 0.03, "lag {lag}: {}", a[lag]);
        }
    }

    #[test]
    fn ar1_acf_geometric() {
        let mut r = Rng::new(4);
        let phi = 0.8f64;
        let mut x = 0.0f64;
        let xs: Vec<f32> = (0..60_000)
            .map(|_| {
                x = phi * x + r.normal();
                x as f32
            })
            .collect();
        let a = acf(&xs, 5);
        for lag in 1..=5 {
            assert!((a[lag] - phi.powi(lag as i32)).abs() < 0.05, "lag {lag}: {}", a[lag]);
        }
    }

    #[test]
    fn clamps_max_lag_to_series_length() {
        let xs = [1.0f32, 2.0, 1.0];
        assert_eq!(acf(&xs, 100).len(), 3);
    }

    #[test]
    fn r2_perfect_for_identical_series() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.07).sin()).collect();
        let r2 = acf_r2(&xs, &xs, 100).unwrap();
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_none_for_constant_series() {
        let flat = [5.0f32; 100];
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        assert_eq!(acf_r2(&xs, &flat, 10), None);
        assert_eq!(acf_r2(&flat, &xs, 10), None);
    }

    #[test]
    fn r2_low_when_structure_destroyed() {
        // Measured: strongly periodic. Synthetic: white noise.
        let measured: Vec<f32> = (0..4000).map(|i| ((i as f32) * 0.3).sin()).collect();
        let mut r = Rng::new(5);
        let synthetic: Vec<f32> = (0..4000).map(|_| r.normal() as f32).collect();
        let r2 = acf_r2(&measured, &synthetic, 60).unwrap();
        assert!(r2 < 0.3, "r2 {r2}");
    }

    #[test]
    fn r2_detects_matching_ar_structure() {
        let gen = |seed: u64, phi: f64| {
            let mut r = Rng::new(seed);
            let mut x = 0.0f64;
            (0..30_000)
                .map(|_| {
                    x = phi * x + r.normal();
                    x as f32
                })
                .collect::<Vec<f32>>()
        };
        let a = gen(1, 0.9);
        let b = gen(2, 0.9);
        let c = gen(3, 0.0);
        assert!(acf_r2(&a, &b, 40).unwrap() > 0.95);
        assert!(acf_r2(&a, &c, 40).unwrap() < 0.2);
    }
}
