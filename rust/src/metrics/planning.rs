//! Planner-facing load-shape statistics (paper Table 3 and §4.5):
//! peak, average, energy, peak-to-average ratio, maximum ramp rate at a
//! given interval, load factor, coefficient of variation, and percentiles
//! — plus the **streaming** variants ([`StreamingPlanningStats`],
//! [`StreamingResampler`], [`StreamingHistogram`], [`StreamingRamps`]) the
//! >24 h windowed facility path and the site composition engine
//! ([`crate::site`]) fold per window without materializing the series.
//!
//! Error handling: these functions sit directly under user-supplied sweep
//! JSON (`dt`, export intervals) and generated series that can, in
//! degenerate scenarios, be empty — so invalid inputs are `anyhow` errors
//! surfaced by the CLI, never panics. Non-finite (NaN) samples are
//! **ignored** by [`percentile`] (documented policy; a NaN can never abort
//! a multi-hour run), and sorting uses `f32::total_cmp`, which is total
//! over every bit pattern.

use anyhow::{ensure, Result};

/// Summary statistics of a facility/row/rack power series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanningStats {
    pub peak_w: f64,
    pub avg_w: f64,
    /// 99th-percentile power — the paper's oversubscription operating point.
    pub p99_w: f64,
    /// Total energy over the series, from the raw samples and the true
    /// elapsed time (`Σ P·dt`), **not** from a resampled series — the
    /// partial trailing resample window carries no weight bias here.
    pub energy_kwh: f64,
    pub peak_to_average: f64,
    /// Max |ΔP| between consecutive aggregated intervals (W per interval).
    pub max_ramp_w: f64,
    /// avg / peak — the utility "load factor".
    pub load_factor: f64,
    /// Coefficient of variation σ/μ (the §4.5 smoothing metric).
    pub cv: f64,
}

impl PlanningStats {
    /// Compute stats over `series` (sampled at `dt_s`), with ramps measured
    /// on `ramp_interval_s` averages (the paper uses 15-minute ramps).
    ///
    /// Errors on an empty series or non-positive `dt_s` /
    /// `ramp_interval_s` (both reachable from sweep JSON).
    pub fn compute(series: &[f32], dt_s: f64, ramp_interval_s: f64) -> Result<PlanningStats> {
        ensure!(!series.is_empty(), "planning stats: empty power series");
        ensure!(
            dt_s.is_finite() && dt_s > 0.0,
            "planning stats: dt must be positive seconds (got {dt_s})"
        );
        let peak = series.iter().fold(f64::NEG_INFINITY, |m, &x| m.max(x as f64));
        let sum = series.iter().map(|&x| x as f64).sum::<f64>();
        let avg = sum / series.len() as f64;
        let ramp = max_ramp(series, dt_s, ramp_interval_s)?;
        Ok(PlanningStats {
            peak_w: peak,
            avg_w: avg,
            p99_w: percentile(series, 99.0)?,
            energy_kwh: joules_to_kwh(sum * dt_s),
            peak_to_average: if avg.abs() > 1e-12 { peak / avg } else { f64::INFINITY },
            max_ramp_w: ramp,
            load_factor: if peak.abs() > 1e-12 { avg / peak } else { 0.0 },
            cv: coefficient_of_variation(series)?,
        })
    }
}

/// Joules → kWh (`J / 3.6e6`), the one spelling of the energy-unit
/// conversion shared by the planning-stats folds and the net-load overlay
/// accounting ([`crate::site::OverlaySummary`]) — their `energy_kwh` /
/// `*_kwh` columns must agree bit-for-bit on identical integrals.
#[inline]
pub fn joules_to_kwh(joules: f64) -> f64 {
    joules / 3.6e6
}

/// Clamp a requested ramp-measurement interval to a series: at most half
/// the horizon (so at least two windows exist and the ramp is measured
/// instead of identically zero) and at least `dt_s`. The one clamp policy
/// shared by the sweep runner, the facility CLI, and the site composition
/// engine — their `max_ramp_w` columns must agree on identical series.
pub fn clamp_ramp_interval(ramp_interval_s: f64, horizon_s: f64, dt_s: f64) -> f64 {
    ramp_interval_s.min(horizon_s / 2.0).max(dt_s)
}

/// Samples per resampling window: `interval_s / dt_s` rounded, clamped to
/// at least 1. The single source of truth for windowing geometry, shared
/// by [`resample_mean`], the aggregate module's f64 resampler, and the
/// streaming export writers. Errors on non-positive or non-finite inputs
/// (reachable from sweep JSON `dt` / export intervals).
pub fn resample_stride(dt_s: f64, interval_s: f64) -> Result<usize> {
    ensure!(
        dt_s.is_finite() && dt_s > 0.0,
        "resample: dt must be positive seconds (got {dt_s})"
    );
    ensure!(
        interval_s.is_finite() && interval_s > 0.0,
        "resample: interval must be positive seconds (got {interval_s})"
    );
    Ok((interval_s / dt_s).round().max(1.0) as usize)
}

/// Average `series` (at `dt_s`) into windows of `interval_s`. The last
/// partial window is averaged over its **actual** length — consumers that
/// weight resampled points by `interval_s` (energy integrals) must use
/// [`resample_mean_with_tail`] to learn the true weight of the final point.
pub fn resample_mean(series: &[f32], dt_s: f64, interval_s: f64) -> Result<Vec<f32>> {
    Ok(resample_mean_with_tail(series, dt_s, interval_s)?.0)
}

/// [`resample_mean`] plus the sample count of the final window: equal to
/// the stride when the horizon divides evenly, smaller for a partial
/// trailing window, `0` for an empty series. Multiplying every resampled
/// point by `interval_s` overstates tail energy unless the final point is
/// weighted by `tail_count · dt_s` instead.
pub fn resample_mean_with_tail(
    series: &[f32],
    dt_s: f64,
    interval_s: f64,
) -> Result<(Vec<f32>, usize)> {
    let stride = resample_stride(dt_s, interval_s)?;
    let out: Vec<f32> = series
        .chunks(stride)
        .map(|c| (c.iter().map(|&x| x as f64).sum::<f64>() / c.len() as f64) as f32)
        .collect();
    let tail = match series.len() % stride {
        0 if series.is_empty() => 0,
        0 => stride,
        r => r,
    };
    Ok((out, tail))
}

/// Maximum absolute difference between consecutive `interval_s` averages.
pub fn max_ramp(series: &[f32], dt_s: f64, interval_s: f64) -> Result<f64> {
    let agg = resample_mean(series, dt_s, interval_s)?;
    Ok(agg.windows(2).map(|w| (w[1] as f64 - w[0] as f64).abs()).fold(0.0, f64::max))
}

/// Peak-to-average ratio.
pub fn peak_to_average(series: &[f32]) -> Result<f64> {
    Ok(PlanningStats::compute(series, 1.0, 1.0)?.peak_to_average)
}

/// Coefficient of variation σ/μ (paper §4.5: 0.583 server → 0.127 site).
/// Errors on an empty series.
pub fn coefficient_of_variation(series: &[f32]) -> Result<f64> {
    ensure!(!series.is_empty(), "coefficient of variation: empty series");
    let n = series.len() as f64;
    let mean = series.iter().map(|&x| x as f64).sum::<f64>() / n;
    if mean.abs() < 1e-12 {
        return Ok(0.0);
    }
    let var = series.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    Ok(var.sqrt() / mean)
}

/// p-th percentile (0..=100) with linear interpolation. NaN samples are
/// ignored; errors if no non-NaN sample remains or `p` is out of range.
pub fn percentile(series: &[f32], p: f64) -> Result<f64> {
    ensure!(
        (0.0..=100.0).contains(&p),
        "percentile: p must be in [0, 100] (got {p})"
    );
    let mut v: Vec<f32> = series.iter().copied().filter(|x| !x.is_nan()).collect();
    ensure!(
        !v.is_empty(),
        "percentile: no finite samples ({} NaN of {} total)",
        series.len() - v.len(),
        series.len()
    );
    v.sort_by(f32::total_cmp);
    Ok(percentile_of_sorted(&v, p))
}

/// The interpolation step of [`percentile`] over an already-sorted,
/// NaN-free, non-empty slice — shared so batched quantile readers
/// ([`StreamingPlanningStats::quantiles`]) sort once and stay
/// bit-identical to per-call [`percentile`].
fn percentile_of_sorted(v: &[f32], p: f64) -> f64 {
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo] as f64
    } else {
        let w = rank - lo as f64;
        v[lo] as f64 * (1.0 - w) + v[hi] as f64 * w
    }
}

// ---------------------------------------------------------------------------
// Streaming statistics — the >24 h windowed path
// ---------------------------------------------------------------------------

/// Incremental mean-resampler: feeds samples in series order, emits each
/// completed `interval_s` window's mean, and carries the partial sum
/// across arbitrary push boundaries. Emitted values are **bit-identical**
/// to [`resample_mean`] / the aggregate module's f64 resampler on the
/// concatenated series: chunk boundaries fall at the same stride
/// multiples, each chunk's sum is a fresh left-to-right f64 fold from 0.0,
/// and the emitted value is `((sum / count) * scale) as f32` — the exact
/// expression of the batch resamplers.
#[derive(Debug, Clone)]
pub struct StreamingResampler {
    stride: usize,
    scale: f64,
    sum: f64,
    count: usize,
}

impl StreamingResampler {
    /// `scale` multiplies each emitted mean (the aggregate module uses it
    /// to apply PUE without an intermediate buffer); pass `1.0` otherwise.
    pub fn new(dt_s: f64, interval_s: f64, scale: f64) -> Result<StreamingResampler> {
        Ok(StreamingResampler { stride: resample_stride(dt_s, interval_s)?, scale, sum: 0.0, count: 0 })
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Feed one sample; returns the window mean when this sample completes
    /// a window.
    #[inline]
    pub fn push(&mut self, x: f64) -> Option<f32> {
        self.sum += x;
        self.count += 1;
        if self.count == self.stride {
            let v = ((self.sum / self.count as f64) * self.scale) as f32;
            self.sum = 0.0;
            self.count = 0;
            Some(v)
        } else {
            None
        }
    }

    /// Feed a slice, appending every completed window mean to `out`.
    pub fn push_slice(&mut self, xs: &[f64], out: &mut Vec<f32>) {
        for &x in xs {
            if let Some(v) = self.push(x) {
                out.push(v);
            }
        }
    }

    /// Drain the trailing partial window, if any: `(mean, sample_count)`
    /// with the mean over the **actual** count — the streaming equivalent
    /// of [`resample_mean_with_tail`]'s final point.
    pub fn flush(&mut self) -> Option<(f32, usize)> {
        if self.count == 0 {
            return None;
        }
        let v = ((self.sum / self.count as f64) * self.scale) as f32;
        let n = self.count;
        self.sum = 0.0;
        self.count = 0;
        Some((v, n))
    }
}

/// Number of bins in the streaming quantile histogram. With the
/// doubling-collapse growth rule the final bin width is at most
/// `2·max_sample / QUANTILE_BINS`, so any quantile estimate is within
/// half a bin of the nearest-rank sample quantile — **≤ `peak_w /
/// QUANTILE_BINS`** absolute error (≈ 0.024 % of peak at 4096 bins); see
/// [`StreamingHistogram::quantile`] for the interpolated-quantile caveat.
pub const QUANTILE_BINS: usize = 4096;

/// Fixed-memory streaming histogram over `[0, width·QUANTILE_BINS)`.
///
/// The bin width is set by the first sample (placing it mid-range) and
/// **doubles** whenever a sample lands beyond the range, merging adjacent
/// bin pairs — so the histogram never rescans data and its error bound
/// (half the final bin width, see [`QUANTILE_BINS`]) is known a
/// posteriori. Samples below zero clamp into bin 0 (facility power is
/// non-negative); NaN samples are ignored, matching [`percentile`].
#[derive(Debug, Clone)]
pub struct StreamingHistogram {
    width: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Default for StreamingHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingHistogram {
    pub fn new() -> StreamingHistogram {
        StreamingHistogram { width: 0.0, bins: vec![0; QUANTILE_BINS], count: 0 }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Half the current bin width — the absolute error bound of
    /// [`StreamingHistogram::quantile`].
    pub fn error_bound(&self) -> f64 {
        0.5 * self.width
    }

    pub fn push(&mut self, x: f64) {
        // Non-finite samples are skipped (matching `percentile`); +inf in
        // particular would make the collapse loop below spin forever once
        // `width` overflowed to inf.
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        if self.count == 0 {
            // First sample lands mid-range; zero gets a tiny width that
            // the collapse rule grows as real magnitudes arrive.
            self.width = (2.0 * x / QUANTILE_BINS as f64).max(1e-12);
        }
        while x >= self.width * QUANTILE_BINS as f64 {
            self.collapse();
        }
        let idx = ((x / self.width) as usize).min(QUANTILE_BINS - 1);
        self.bins[idx] += 1;
        self.count += 1;
    }

    /// Merge adjacent bin pairs, doubling the covered range.
    fn collapse(&mut self) {
        let n = QUANTILE_BINS;
        for i in 0..n / 2 {
            self.bins[i] = self.bins[2 * i] + self.bins[2 * i + 1];
        }
        for b in self.bins[n / 2..].iter_mut() {
            *b = 0;
        }
        self.width *= 2.0;
    }

    /// Estimate the `q`-quantile (`q` in [0, 1]) as the midpoint of the
    /// bin holding rank `⌊q·(n−1)⌋` — within [`error_bound`] (half a bin
    /// width) of the **nearest-rank** sample quantile. The linearly
    /// interpolated quantile ([`percentile`]) can additionally differ by
    /// up to the gap to the next order statistic, which is negligible for
    /// the dense facility series this backs. Errors when the histogram is
    /// empty.
    ///
    /// [`error_bound`]: StreamingHistogram::error_bound
    pub fn quantile(&self, q: f64) -> Result<f64> {
        ensure!((0.0..=1.0).contains(&q), "quantile: q must be in [0, 1] (got {q})");
        ensure!(self.count > 0, "quantile of empty histogram");
        let target = q * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &b) in self.bins.iter().enumerate() {
            cum += b;
            if b > 0 && cum as f64 > target {
                return Ok(self.width * (i as f64 + 0.5));
            }
        }
        // Unreachable when count > 0; return the top of the range.
        Ok(self.width * QUANTILE_BINS as f64)
    }
}

/// Result of a streamed stats fold: the stats plus how the quantile was
/// obtained.
#[derive(Debug, Clone, Copy)]
pub struct StreamedStats {
    pub stats: PlanningStats,
    /// `true` when the series fit the exact-sample cap and every field —
    /// including p99 and CV — is bit-identical to
    /// [`PlanningStats::compute`] on the buffered series.
    pub exact_quantiles: bool,
    /// Absolute error bound on `stats.p99_w` (0 when exact).
    pub p99_error_bound_w: f64,
}

/// Default cap on retained samples for the exact-quantile fallback:
/// 4 Mi samples ≈ 16 MB — more than a 48 h horizon at 250 ms, so sweep
/// summaries at paper-scale horizons are **unchanged** by streaming.
pub const EXACT_QUANTILE_CAP: usize = 1 << 22;

/// Streaming [`PlanningStats`]: peak, mean, energy, and max-ramp are exact
/// folds (bit-identical to the buffered computation — same f64 fold order,
/// same resample-chunk geometry); p99 and CV come from retained samples
/// while the series fits [`EXACT_QUANTILE_CAP`], and degrade gracefully to
/// a [`StreamingHistogram`] estimate (documented bound) and a
/// sum-of-squares CV beyond it.
#[derive(Debug, Clone)]
pub struct StreamingPlanningStats {
    dt_s: f64,
    ramp_interval_s: f64,
    n: u64,
    sum: f64,
    sumsq: f64,
    peak: f64,
    ramp: StreamingResampler,
    prev_ramp: Option<f32>,
    max_ramp: f64,
    hist: StreamingHistogram,
    exact: Option<Vec<f32>>,
    exact_cap: usize,
}

impl StreamingPlanningStats {
    pub fn new(dt_s: f64, ramp_interval_s: f64) -> Result<StreamingPlanningStats> {
        Self::with_exact_cap(dt_s, ramp_interval_s, EXACT_QUANTILE_CAP)
    }

    /// `exact_cap = 0` forces the histogram path from the first sample
    /// (tests use this to exercise the bound at small horizons).
    pub fn with_exact_cap(
        dt_s: f64,
        ramp_interval_s: f64,
        exact_cap: usize,
    ) -> Result<StreamingPlanningStats> {
        ensure!(
            dt_s.is_finite() && dt_s > 0.0,
            "planning stats: dt must be positive seconds (got {dt_s})"
        );
        Ok(StreamingPlanningStats {
            dt_s,
            ramp_interval_s,
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            peak: f64::NEG_INFINITY,
            ramp: StreamingResampler::new(dt_s, ramp_interval_s, 1.0)?,
            prev_ramp: None,
            max_ramp: 0.0,
            hist: StreamingHistogram::new(),
            exact: Some(Vec::new()),
            exact_cap,
        })
    }

    pub fn samples_seen(&self) -> u64 {
        self.n
    }

    /// Quantile (`q` in [0, 1]) of every sample folded so far: exact
    /// (linearly interpolated, [`percentile`]) while the series fits the
    /// retained-sample cap, histogram-estimated (within
    /// [`StreamingHistogram::error_bound`]) beyond it — the same policy the
    /// p99 in [`StreamingPlanningStats::finalize`] follows, so a
    /// `quantile(0.99)` read always agrees with the finalized `p99_w`.
    /// Site load-duration curves are read through this accessor.
    pub fn quantile(&self, q: f64) -> Result<f64> {
        ensure!((0.0..=1.0).contains(&q), "quantile: q must be in [0, 1] (got {q})");
        match &self.exact {
            Some(buf) => percentile(buf, q * 100.0),
            None => self.hist.quantile(q),
        }
    }

    /// Several quantiles in one pass: on the exact path the retained
    /// buffer is sorted **once** and every point read from the sorted
    /// copy — bit-identical to calling [`StreamingPlanningStats::quantile`]
    /// per point, without re-sorting up to [`EXACT_QUANTILE_CAP`] samples
    /// per read (the load-duration fan-out the site engine performs).
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>> {
        for &q in qs {
            ensure!((0.0..=1.0).contains(&q), "quantile: q must be in [0, 1] (got {q})");
        }
        match &self.exact {
            Some(buf) => {
                let mut v: Vec<f32> = buf.iter().copied().filter(|x| !x.is_nan()).collect();
                ensure!(
                    !v.is_empty(),
                    "percentile: no finite samples ({} NaN of {} total)",
                    buf.len() - v.len(),
                    buf.len()
                );
                v.sort_by(f32::total_cmp);
                Ok(qs.iter().map(|&q| percentile_of_sorted(&v, q * 100.0)).collect())
            }
            None => qs.iter().map(|&q| self.hist.quantile(q)).collect(),
        }
    }

    /// `false` once the exact-sample cap spilled to the histogram (every
    /// [`StreamingPlanningStats::quantile`] read is then bounded, not exact).
    pub fn quantiles_exact(&self) -> bool {
        self.exact.is_some()
    }

    #[inline]
    fn fold_ramp_point(&mut self, v: f32) {
        if let Some(p) = self.prev_ramp {
            self.max_ramp = self.max_ramp.max((v as f64 - p as f64).abs());
        }
        self.prev_ramp = Some(v);
    }

    /// Fold one window of the (PCC, f32) series, in series order.
    pub fn push_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            let xf = x as f64;
            self.peak = self.peak.max(xf);
            self.sum += xf;
            self.sumsq += xf * xf;
            self.n += 1;
            self.hist.push(xf);
            if let Some(v) = self.ramp.push(xf) {
                self.fold_ramp_point(v);
            }
        }
        let keep = match self.exact.as_mut() {
            Some(buf) if buf.len() + xs.len() <= self.exact_cap => {
                buf.extend_from_slice(xs);
                true
            }
            // Over the cap: drop the retained samples (the histogram has
            // seen every sample from the start).
            Some(_) => false,
            None => true,
        };
        if !keep {
            self.exact = None;
        }
    }

    /// Finish the fold. Errors if no samples were pushed.
    pub fn finalize(mut self) -> Result<StreamedStats> {
        ensure!(self.n > 0, "planning stats: empty power series");
        if let Some(buf) = self.exact.take() {
            // Identical to the buffered path, bit for bit.
            return Ok(StreamedStats {
                stats: PlanningStats::compute(&buf, self.dt_s, self.ramp_interval_s)?,
                exact_quantiles: true,
                p99_error_bound_w: 0.0,
            });
        }
        // The trailing partial resample window participates in the ramp,
        // exactly as resample_mean's final chunk does.
        if let Some((v, _count)) = self.ramp.flush() {
            self.fold_ramp_point(v);
        }
        let n = self.n as f64;
        let avg = self.sum / n;
        let cv = if avg.abs() < 1e-12 {
            0.0
        } else {
            (self.sumsq / n - avg * avg).max(0.0).sqrt() / avg
        };
        Ok(StreamedStats {
            stats: PlanningStats {
                peak_w: self.peak,
                avg_w: avg,
                p99_w: self.hist.quantile(0.99)?,
                energy_kwh: joules_to_kwh(self.sum * self.dt_s),
                peak_to_average: if avg.abs() > 1e-12 { self.peak / avg } else { f64::INFINITY },
                max_ramp_w: self.max_ramp,
                load_factor: if self.peak.abs() > 1e-12 { avg / self.peak } else { 0.0 },
                cv,
            },
            exact_quantiles: false,
            p99_error_bound_w: self.hist.error_bound(),
        })
    }
}

/// Summary of the ramp-rate distribution at one utility interval — what an
/// interconnection study reads off the composed site profile: how fast the
/// load moves between consecutive settlement intervals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampStats {
    /// Measurement interval (s): consecutive `interval_s` means.
    pub interval_s: f64,
    /// Max |ΔP| between consecutive interval means (W per interval).
    pub max_w: f64,
    /// 99th percentile of |ΔP| (W per interval); 0 when fewer than two
    /// intervals completed.
    pub p99_w: f64,
    /// Number of interval-to-interval ramps measured.
    pub n_ramps: usize,
}

/// Streaming ramp-rate distribution at one utility interval: folds the
/// series sample-by-sample (any push partition — window boundaries never
/// matter), resamples to `interval_s` means through the shared
/// [`StreamingResampler`] geometry, and records every |ΔP| between
/// consecutive means. Retained memory is the ramp list itself —
/// O(horizon / interval), i.e. ~2 000 entries for a week at 5 min — so a
/// full distribution (not just the max) stays exact at planning horizons.
/// The trailing partial interval participates exactly as
/// [`resample_mean`]'s final chunk does (via [`StreamingRamps::finalize`]).
#[derive(Debug, Clone)]
pub struct StreamingRamps {
    interval_s: f64,
    res: StreamingResampler,
    prev: Option<f32>,
    /// |ΔP| per completed interval pair, kept in f64: the difference of
    /// two f32 interval means is exact in f64 but not always
    /// f32-representable, and [`max_ramp`] keeps it in f64 — storing f32
    /// here would break bit-identity with the buffered fold.
    ramps: Vec<f64>,
}

impl StreamingRamps {
    pub fn new(dt_s: f64, interval_s: f64) -> Result<StreamingRamps> {
        Ok(StreamingRamps {
            interval_s,
            res: StreamingResampler::new(dt_s, interval_s, 1.0)?,
            prev: None,
            ramps: Vec::new(),
        })
    }

    pub fn interval_s(&self) -> f64 {
        self.interval_s
    }

    fn fold_point(&mut self, v: f32) {
        if let Some(p) = self.prev {
            self.ramps.push((v as f64 - p as f64).abs());
        }
        self.prev = Some(v);
    }

    /// Fold one window of the series, in series order.
    pub fn push_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            if let Some(v) = self.res.push(x as f64) {
                self.fold_point(v);
            }
        }
    }

    /// Flush the trailing partial interval and summarize the distribution.
    pub fn finalize(mut self) -> Result<RampStats> {
        if let Some((v, _count)) = self.res.flush() {
            self.fold_point(v);
        }
        let n_ramps = self.ramps.len();
        let max_w = self.ramps.iter().fold(0.0f64, |m, &x| m.max(x));
        let p99_w = if self.ramps.is_empty() {
            0.0
        } else {
            // `percentile`'s linear interpolation, over the f64 ramps
            // (ramps are differences of finite means — never NaN).
            let mut v = self.ramps;
            v.sort_by(f64::total_cmp);
            let rank = 0.99 * (v.len() - 1) as f64;
            let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
            if lo == hi {
                v[lo]
            } else {
                let w = rank - lo as f64;
                v[lo] * (1.0 - w) + v[hi] * w
            }
        };
        Ok(RampStats { interval_s: self.interval_s, max_w, p99_w, n_ramps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_flat_series() {
        let s = PlanningStats::compute(&[100.0f32; 16], 0.25, 1.0).unwrap();
        assert_eq!(s.peak_w, 100.0);
        assert_eq!(s.avg_w, 100.0);
        assert_eq!(s.p99_w, 100.0);
        assert_eq!(s.peak_to_average, 1.0);
        assert_eq!(s.load_factor, 1.0);
        assert_eq!(s.max_ramp_w, 0.0);
        assert_eq!(s.cv, 0.0);
        // 16 samples × 100 W × 0.25 s = 400 J.
        assert!((s.energy_kwh - 400.0 / 3.6e6).abs() < 1e-15);
    }

    #[test]
    fn stats_on_step_series() {
        // 4 samples at 100 then 4 at 300, dt=1, ramp interval 4 s.
        let series = [100.0f32, 100.0, 100.0, 100.0, 300.0, 300.0, 300.0, 300.0];
        let s = PlanningStats::compute(&series, 1.0, 4.0).unwrap();
        assert_eq!(s.peak_w, 300.0);
        assert_eq!(s.avg_w, 200.0);
        assert!((s.peak_to_average - 1.5).abs() < 1e-12);
        assert!((s.load_factor - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_ramp_w, 200.0);
    }

    #[test]
    fn resample_means_windows() {
        let s = [1.0f32, 3.0, 5.0, 7.0, 9.0];
        assert_eq!(resample_mean(&s, 1.0, 2.0).unwrap(), vec![2.0, 6.0, 9.0]);
        // stride of 1 is identity
        assert_eq!(resample_mean(&s, 1.0, 1.0).unwrap(), s.to_vec());
        // interval smaller than dt clamps to stride 1
        assert_eq!(resample_mean(&s, 1.0, 0.1).unwrap(), s.to_vec());
    }

    #[test]
    fn resample_empty_series_is_empty() {
        assert!(resample_mean(&[], 0.25, 1.0).unwrap().is_empty());
        assert_eq!(max_ramp(&[], 0.25, 1.0).unwrap(), 0.0);
        assert_eq!(resample_mean_with_tail(&[], 0.25, 1.0).unwrap().1, 0);
    }

    #[test]
    fn resample_non_divisible_interval_rounds_stride() {
        // interval/dt = 0.3/0.25 = 1.2 → stride rounds to 1 (identity);
        // 0.4/0.25 = 1.6 → stride 2.
        let s = [2.0f32, 4.0, 6.0, 8.0];
        assert_eq!(resample_mean(&s, 0.25, 0.3).unwrap(), s.to_vec());
        assert_eq!(resample_mean(&s, 0.25, 0.4).unwrap(), vec![3.0, 7.0]);
        // Trailing partial window is averaged over its actual length.
        let s = [2.0f32, 4.0, 6.0];
        assert_eq!(resample_mean(&s, 0.25, 0.5).unwrap(), vec![3.0, 6.0]);
    }

    #[test]
    fn resample_with_tail_reports_partial_window_weight() {
        let s = [2.0f32, 4.0, 6.0];
        let (agg, tail) = resample_mean_with_tail(&s, 0.25, 0.5).unwrap();
        assert_eq!(agg, vec![3.0, 6.0]);
        assert_eq!(tail, 1); // last window holds one 0.25 s sample
        let (_, tail) = resample_mean_with_tail(&[1.0f32; 8], 0.25, 0.5).unwrap();
        assert_eq!(tail, 2); // exact division: full stride
        // Energy with the tail weight matches the raw integral; the naive
        // interval weighting overstates it (the satellite bug).
        let dt = 0.25;
        let raw_j: f64 = s.iter().map(|&x| x as f64 * dt).sum();
        let interval = 0.5;
        let stride = resample_stride(dt, interval).unwrap();
        let mut corrected = 0.0f64;
        for (i, &v) in agg.iter().enumerate() {
            let w = if i + 1 == agg.len() { tail as f64 * dt } else { stride as f64 * dt };
            corrected += v as f64 * w;
        }
        let naive: f64 = agg.iter().map(|&v| v as f64 * interval).sum();
        assert!((corrected - raw_j).abs() < 1e-9);
        assert!(naive > raw_j + 1e-9, "naive {naive} should overstate {raw_j}");
    }

    #[test]
    fn invalid_inputs_error_instead_of_panicking() {
        assert!(PlanningStats::compute(&[], 0.25, 1.0).is_err());
        assert!(PlanningStats::compute(&[1.0], 0.0, 1.0).is_err());
        assert!(PlanningStats::compute(&[1.0], 0.25, -5.0).is_err());
        assert!(resample_stride(0.0, 1.0).is_err());
        assert!(resample_stride(0.25, 0.0).is_err());
        assert!(resample_stride(f64::NAN, 1.0).is_err());
        assert!(resample_mean(&[1.0], 0.25, f64::INFINITY).is_err());
        assert!(coefficient_of_variation(&[]).is_err());
        assert!(percentile(&[], 50.0).is_err());
        assert!(percentile(&[1.0], 101.0).is_err());
        assert!(percentile(&[1.0], -0.5).is_err());
        assert!(percentile(&[f32::NAN, f32::NAN], 50.0).is_err());
        assert!(StreamingPlanningStats::new(0.0, 900.0).is_err());
        assert!(StreamingPlanningStats::new(1.0, 900.0).unwrap().finalize().is_err());
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        let s = [1.0f32, f32::NAN, 2.0, 3.0, f32::NAN, 4.0, 5.0];
        assert_eq!(percentile(&s, 50.0).unwrap(), 3.0);
        assert_eq!(percentile(&s, 100.0).unwrap(), 5.0);
        // And the old panic path (partial_cmp unwrap) is gone.
        assert_eq!(percentile(&[f32::NAN, 7.0], 0.0).unwrap(), 7.0);
    }

    #[test]
    fn stats_p99_and_cv_track_distribution() {
        // 99 samples at 100 W and one spike at 300 W.
        let mut s = vec![100.0f32; 99];
        s.push(300.0);
        let st = PlanningStats::compute(&s, 1.0, 10.0).unwrap();
        assert_eq!(st.peak_w, 300.0);
        assert!(st.p99_w > 100.0 && st.p99_w < 300.0, "p99 {}", st.p99_w);
        assert!((st.cv - coefficient_of_variation(&s).unwrap()).abs() < 1e-12);
        assert!(st.cv > 0.0);
    }

    #[test]
    fn resample_preserves_total_energy_on_exact_windows() {
        let s: Vec<f32> = (0..120).map(|i| (i % 7) as f32 * 10.0).collect();
        let agg = resample_mean(&s, 0.25, 1.0).unwrap(); // windows of 4
        let e1: f64 = s.iter().map(|&x| x as f64 * 0.25).sum();
        let e2: f64 = agg.iter().map(|&x| x as f64 * 1.0).sum();
        assert!((e1 - e2).abs() < 1e-6);
    }

    #[test]
    fn cov_known_values() {
        assert_eq!(coefficient_of_variation(&[5.0f32; 10]).unwrap(), 0.0);
        let s = [0.0f32, 2.0]; // mean 1, std 1
        assert!((coefficient_of_variation(&s).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.0).unwrap(), 1.0);
        assert_eq!(percentile(&s, 100.0).unwrap(), 5.0);
        assert_eq!(percentile(&s, 50.0).unwrap(), 3.0);
        assert!((percentile(&s, 95.0).unwrap() - 4.8).abs() < 1e-9);
    }

    #[test]
    fn ramp_uses_interval_averages_not_raw_samples() {
        // A single-sample spike shouldn't dominate a 4-sample-interval ramp.
        let mut s = vec![100.0f32; 16];
        s[8] = 500.0;
        let ramp = max_ramp(&s, 1.0, 4.0).unwrap();
        assert!((ramp - 100.0).abs() < 1e-9); // window mean jumps by 100
    }

    // -- streaming --

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| 1000.0 + 400.0 * ((i as f32) * 0.13).sin() + (i % 11) as f32).collect()
    }

    #[test]
    fn streaming_resampler_matches_batch_resampler_bitwise() {
        let s = wavy(1003); // not a multiple of any stride below
        for interval in [1.0, 2.5, 7.0] {
            let reference = resample_mean(&s, 0.25, interval).unwrap();
            let mut r = StreamingResampler::new(0.25, interval, 1.0).unwrap();
            let mut out = Vec::new();
            // Ragged pushes that straddle chunk boundaries.
            for chunk in s.chunks(17) {
                let xs: Vec<f64> = chunk.iter().map(|&x| x as f64).collect();
                r.push_slice(&xs, &mut out);
            }
            if let Some((v, _)) = r.flush() {
                out.push(v);
            }
            assert_eq!(out.len(), reference.len(), "interval {interval}");
            for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "interval {interval} point {i}");
            }
        }
    }

    #[test]
    fn streaming_stats_exact_path_is_bit_identical() {
        let s = wavy(5000);
        let reference = PlanningStats::compute(&s, 0.25, 9.0).unwrap();
        let mut st = StreamingPlanningStats::new(0.25, 9.0).unwrap();
        for chunk in s.chunks(37) {
            st.push_slice(chunk);
        }
        let out = st.finalize().unwrap();
        assert!(out.exact_quantiles);
        assert_eq!(out.stats, reference);
        assert_eq!(out.p99_error_bound_w, 0.0);
    }

    #[test]
    fn streaming_stats_histogram_path_exact_folds_and_bounded_p99() {
        let s = wavy(5000);
        let reference = PlanningStats::compute(&s, 0.25, 9.0).unwrap();
        // Cap 0: histogram path from sample one.
        let mut st = StreamingPlanningStats::with_exact_cap(0.25, 9.0, 0).unwrap();
        for chunk in s.chunks(41) {
            st.push_slice(chunk);
        }
        let out = st.finalize().unwrap();
        assert!(!out.exact_quantiles);
        // Exact folds: bit-identical.
        assert_eq!(out.stats.peak_w.to_bits(), reference.peak_w.to_bits());
        assert_eq!(out.stats.avg_w.to_bits(), reference.avg_w.to_bits());
        assert_eq!(out.stats.energy_kwh.to_bits(), reference.energy_kwh.to_bits());
        assert_eq!(out.stats.max_ramp_w.to_bits(), reference.max_ramp_w.to_bits());
        // p99 within the documented bound of the nearest-rank quantile.
        assert!(out.p99_error_bound_w > 0.0);
        let mut sorted = s.clone();
        sorted.sort_by(f32::total_cmp);
        let nearest_rank = sorted[(0.99 * (sorted.len() - 1) as f64).floor() as usize] as f64;
        assert!(
            (out.stats.p99_w - nearest_rank).abs() <= out.p99_error_bound_w,
            "p99 {} vs nearest-rank {} (bound {})",
            out.stats.p99_w,
            nearest_rank,
            out.p99_error_bound_w
        );
        // And close to the interpolated quantile on dense data (the bound
        // plus at most one order-statistic gap).
        assert!(
            (out.stats.p99_w - reference.p99_w).abs() <= out.p99_error_bound_w + 1.0,
            "p99 {} vs interpolated {} (bound {})",
            out.stats.p99_w,
            reference.p99_w,
            out.p99_error_bound_w
        );
        // The bound itself is tight: ≤ peak / QUANTILE_BINS.
        assert!(out.p99_error_bound_w <= reference.peak_w / QUANTILE_BINS as f64 + 1e-9);
        // CV approximation is close (not exact).
        assert!((out.stats.cv - reference.cv).abs() < 1e-6);
    }

    #[test]
    fn streaming_histogram_collapse_keeps_all_mass() {
        let mut h = StreamingHistogram::new();
        // First sample small, later samples 6 orders of magnitude larger:
        // forces many collapses.
        h.push(1.0);
        for i in 0..1000 {
            h.push(1e6 + i as f64);
        }
        assert_eq!(h.count(), 1001);
        let q = h.quantile(0.5).unwrap();
        assert!((q - 1e6).abs() < 2.0 * h.error_bound() + 1000.0, "median {q}");
        assert!(h.error_bound() <= 2.0 * 1.001e6 / QUANTILE_BINS as f64);
    }

    #[test]
    fn streaming_ramps_match_max_ramp_and_survive_ragged_windows() {
        let s = wavy(1003);
        let (dt, interval) = (0.25, 7.0);
        let reference = max_ramp(&s, dt, interval).unwrap();
        // Fold in ragged windows; partition must not matter.
        for chunk_len in [1usize, 13, 64, 1003] {
            let mut r = StreamingRamps::new(dt, interval).unwrap();
            for chunk in s.chunks(chunk_len) {
                r.push_slice(chunk);
            }
            let out = r.finalize().unwrap();
            assert_eq!(out.max_w.to_bits(), reference.to_bits(), "chunk {chunk_len}");
            assert!(out.p99_w <= out.max_w);
            assert!(out.n_ramps > 0);
            assert_eq!(out.interval_s, interval);
        }
        // Degenerate: fewer than two intervals → zero ramps, zero stats.
        let mut r = StreamingRamps::new(1.0, 100.0).unwrap();
        r.push_slice(&[5.0; 3]);
        let out = r.finalize().unwrap();
        assert_eq!(out.n_ramps, 0);
        assert_eq!(out.max_w, 0.0);
        assert_eq!(out.p99_w, 0.0);
    }

    #[test]
    fn streaming_quantile_accessor_tracks_both_paths() {
        let s = wavy(2000);
        // Exact path: agrees with `percentile` bit-for-bit.
        let mut st = StreamingPlanningStats::new(0.25, 9.0).unwrap();
        st.push_slice(&s);
        assert!(st.quantiles_exact());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(
                st.quantile(q).unwrap().to_bits(),
                percentile(&s, q * 100.0).unwrap().to_bits(),
                "q {q}"
            );
        }
        assert!(st.quantile(1.5).is_err());
        let p99_before = st.quantile(0.99).unwrap();
        let fin = st.finalize().unwrap();
        assert_eq!(fin.stats.p99_w.to_bits(), p99_before.to_bits());
        // Histogram path: within the documented bound of nearest-rank.
        let mut st = StreamingPlanningStats::with_exact_cap(0.25, 9.0, 0).unwrap();
        st.push_slice(&s);
        assert!(!st.quantiles_exact());
        let mut sorted = s.clone();
        sorted.sort_by(f32::total_cmp);
        let nearest = sorted[(0.5 * (sorted.len() - 1) as f64).floor() as usize] as f64;
        let q50 = st.quantile(0.5).unwrap();
        let fin = st.finalize().unwrap();
        assert!((q50 - nearest).abs() <= fin.p99_error_bound_w + 1e-9, "q50 {q50} vs {nearest}");
    }

    #[test]
    fn streaming_stats_cap_boundary_drops_to_histogram() {
        let s = wavy(100);
        let mut st = StreamingPlanningStats::with_exact_cap(1.0, 10.0, 64).unwrap();
        st.push_slice(&s[..60]);
        st.push_slice(&s[60..]); // 100 > 64 → spills
        let out = st.finalize().unwrap();
        assert!(!out.exact_quantiles);
        let reference = PlanningStats::compute(&s, 1.0, 10.0).unwrap();
        assert_eq!(out.stats.peak_w, reference.peak_w);
        assert_eq!(out.stats.avg_w, reference.avg_w);
    }
}
