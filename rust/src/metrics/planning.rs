//! Planner-facing load-shape statistics (paper Table 3 and §4.5):
//! peak, average, peak-to-average ratio, maximum ramp rate at a given
//! interval, load factor, coefficient of variation, and percentiles.

/// Summary statistics of a facility/row/rack power series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanningStats {
    pub peak_w: f64,
    pub avg_w: f64,
    /// 99th-percentile power — the paper's oversubscription operating point.
    pub p99_w: f64,
    pub peak_to_average: f64,
    /// Max |ΔP| between consecutive aggregated intervals (W per interval).
    pub max_ramp_w: f64,
    /// avg / peak — the utility "load factor".
    pub load_factor: f64,
    /// Coefficient of variation σ/μ (the §4.5 smoothing metric).
    pub cv: f64,
}

impl PlanningStats {
    /// Compute stats over `series` (sampled at `dt_s`), with ramps measured
    /// on `ramp_interval_s` averages (the paper uses 15-minute ramps).
    pub fn compute(series: &[f32], dt_s: f64, ramp_interval_s: f64) -> PlanningStats {
        assert!(!series.is_empty(), "PlanningStats: empty series");
        let peak = series.iter().fold(f64::NEG_INFINITY, |m, &x| m.max(x as f64));
        let avg = series.iter().map(|&x| x as f64).sum::<f64>() / series.len() as f64;
        let ramp = max_ramp(series, dt_s, ramp_interval_s);
        PlanningStats {
            peak_w: peak,
            avg_w: avg,
            p99_w: percentile(series, 99.0),
            peak_to_average: if avg.abs() > 1e-12 { peak / avg } else { f64::INFINITY },
            max_ramp_w: ramp,
            load_factor: if peak.abs() > 1e-12 { avg / peak } else { 0.0 },
            cv: coefficient_of_variation(series),
        }
    }
}

/// Samples per resampling window: `interval_s / dt_s` rounded, clamped to
/// at least 1. The single source of truth for windowing geometry, shared
/// by [`resample_mean`] and the aggregate module's f64 resampler.
pub fn resample_stride(dt_s: f64, interval_s: f64) -> usize {
    assert!(dt_s > 0.0 && interval_s > 0.0);
    (interval_s / dt_s).round().max(1.0) as usize
}

/// Average `series` (at `dt_s`) into windows of `interval_s` (the last
/// partial window is averaged over its actual length).
pub fn resample_mean(series: &[f32], dt_s: f64, interval_s: f64) -> Vec<f32> {
    series
        .chunks(resample_stride(dt_s, interval_s))
        .map(|c| (c.iter().map(|&x| x as f64).sum::<f64>() / c.len() as f64) as f32)
        .collect()
}

/// Maximum absolute difference between consecutive `interval_s` averages.
pub fn max_ramp(series: &[f32], dt_s: f64, interval_s: f64) -> f64 {
    let agg = resample_mean(series, dt_s, interval_s);
    agg.windows(2).map(|w| (w[1] as f64 - w[0] as f64).abs()).fold(0.0, f64::max)
}

/// Peak-to-average ratio.
pub fn peak_to_average(series: &[f32]) -> f64 {
    PlanningStats::compute(series, 1.0, 1.0).peak_to_average
}

/// Coefficient of variation σ/μ (paper §4.5: 0.583 server → 0.127 site).
pub fn coefficient_of_variation(series: &[f32]) -> f64 {
    assert!(!series.is_empty());
    let n = series.len() as f64;
    let mean = series.iter().map(|&x| x as f64).sum::<f64>() / n;
    if mean.abs() < 1e-12 {
        return 0.0;
    }
    let var = series.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// p-th percentile (0..=100) with linear interpolation.
pub fn percentile(series: &[f32], p: f64) -> f64 {
    assert!(!series.is_empty() && (0.0..=100.0).contains(&p));
    let mut v: Vec<f32> = series.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo] as f64
    } else {
        let w = rank - lo as f64;
        v[lo] as f64 * (1.0 - w) + v[hi] as f64 * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_flat_series() {
        let s = PlanningStats::compute(&[100.0f32; 16], 0.25, 1.0);
        assert_eq!(s.peak_w, 100.0);
        assert_eq!(s.avg_w, 100.0);
        assert_eq!(s.p99_w, 100.0);
        assert_eq!(s.peak_to_average, 1.0);
        assert_eq!(s.load_factor, 1.0);
        assert_eq!(s.max_ramp_w, 0.0);
        assert_eq!(s.cv, 0.0);
    }

    #[test]
    fn stats_on_step_series() {
        // 4 samples at 100 then 4 at 300, dt=1, ramp interval 4 s.
        let series = [100.0f32, 100.0, 100.0, 100.0, 300.0, 300.0, 300.0, 300.0];
        let s = PlanningStats::compute(&series, 1.0, 4.0);
        assert_eq!(s.peak_w, 300.0);
        assert_eq!(s.avg_w, 200.0);
        assert!((s.peak_to_average - 1.5).abs() < 1e-12);
        assert!((s.load_factor - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_ramp_w, 200.0);
    }

    #[test]
    fn resample_means_windows() {
        let s = [1.0f32, 3.0, 5.0, 7.0, 9.0];
        assert_eq!(resample_mean(&s, 1.0, 2.0), vec![2.0, 6.0, 9.0]);
        // stride of 1 is identity
        assert_eq!(resample_mean(&s, 1.0, 1.0), s.to_vec());
        // interval smaller than dt clamps to stride 1
        assert_eq!(resample_mean(&s, 1.0, 0.1), s.to_vec());
    }

    #[test]
    fn resample_empty_series_is_empty() {
        assert!(resample_mean(&[], 0.25, 1.0).is_empty());
        assert_eq!(max_ramp(&[], 0.25, 1.0), 0.0);
    }

    #[test]
    fn resample_non_divisible_interval_rounds_stride() {
        // interval/dt = 0.3/0.25 = 1.2 → stride rounds to 1 (identity);
        // 0.4/0.25 = 1.6 → stride 2.
        let s = [2.0f32, 4.0, 6.0, 8.0];
        assert_eq!(resample_mean(&s, 0.25, 0.3), s.to_vec());
        assert_eq!(resample_mean(&s, 0.25, 0.4), vec![3.0, 7.0]);
        // Trailing partial window is averaged over its actual length.
        let s = [2.0f32, 4.0, 6.0];
        assert_eq!(resample_mean(&s, 0.25, 0.5), vec![3.0, 6.0]);
    }

    #[test]
    fn stats_p99_and_cv_track_distribution() {
        // 99 samples at 100 W and one spike at 300 W.
        let mut s = vec![100.0f32; 99];
        s.push(300.0);
        let st = PlanningStats::compute(&s, 1.0, 10.0);
        assert_eq!(st.peak_w, 300.0);
        assert!(st.p99_w > 100.0 && st.p99_w < 300.0, "p99 {}", st.p99_w);
        assert!((st.cv - coefficient_of_variation(&s)).abs() < 1e-12);
        assert!(st.cv > 0.0);
    }

    #[test]
    fn resample_preserves_total_energy_on_exact_windows() {
        let s: Vec<f32> = (0..120).map(|i| (i % 7) as f32 * 10.0).collect();
        let agg = resample_mean(&s, 0.25, 1.0); // windows of 4
        let e1: f64 = s.iter().map(|&x| x as f64 * 0.25).sum();
        let e2: f64 = agg.iter().map(|&x| x as f64 * 1.0).sum();
        assert!((e1 - e2).abs() < 1e-6);
    }

    #[test]
    fn cov_known_values() {
        assert_eq!(coefficient_of_variation(&[5.0f32; 10]), 0.0);
        let s = [0.0f32, 2.0]; // mean 1, std 1
        assert!((coefficient_of_variation(&s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 5.0);
        assert_eq!(percentile(&s, 50.0), 3.0);
        assert!((percentile(&s, 95.0) - 4.8).abs() < 1e-9);
    }

    #[test]
    fn ramp_uses_interval_averages_not_raw_samples() {
        // A single-sample spike shouldn't dominate a 4-sample-interval ramp.
        let mut s = vec![100.0f32; 16];
        s[8] = 500.0;
        let ramp = max_ramp(&s, 1.0, 4.0);
        assert!((ramp - 100.0).abs() < 1e-9); // window mean jumps by 100
    }
}
