//! The unified run API: one request shape for everything the engine can
//! execute.
//!
//! Historically each run family grew its own entry-point quartet
//! (`run_sweep` / `run_sweep_to` / `run_sweep_sink` /
//! `run_sweep_checkpointed`, mirrored for sites) and its own options
//! struct. This module collapses them behind a single surface:
//!
//! * [`RunSpec`] — *what* to run: a kind-tagged enum over the four
//!   existing spec types. Its JSON form
//!   (`{"kind": "facility|sweep|site|site_sweep", "spec": {...}}`) is the
//!   wire schema of `powertrace serve`;
//! * [`RunOptions`] — *how* to run it: the merged
//!   [`SweepOptions`] / [`SiteOptions`] knob set with a builder. The
//!   PR-7 manifest-identity rule is preserved by delegation: converting
//!   to the legacy structs ([`RunOptions::to_sweep`] /
//!   [`RunOptions::to_site`]) reuses their `identity_json`, so existing
//!   checkpoint manifests keep hashing identically;
//! * [`RunRequest`] = spec + options, and [`execute`] /
//!   [`execute_prepared`] / [`execute_checkpointed`] run it, routing every
//!   kind through the same sink-generic `pub(crate)` engines the
//!   deprecated wrappers use. A facility run is executed as a degenerate
//!   one-cell sweep (same engine, same export layout, cell id
//!   `w0-t0-f0-s<seed>`).
//!
//! The `*_prepared` variants take `&Generator` — the seam that lets one
//! warm generator (artifact + classifier + packed-weight caches) serve
//! many concurrent runs, which is what the serve layer does: prepare
//! under a write lock, execute under read locks.

use crate::config::ScenarioSpec;
use crate::coordinator::Generator;
use crate::export::TraceSink;
use crate::robust::RetryPolicy;
use crate::scenarios::grid::GridDefaults;
use crate::scenarios::runner::{grid_config_ids_used, prepare_sweep, sweep_prepared_sink};
#[cfg(feature = "host")]
use crate::scenarios::runner::sweep_checkpointed_prepared;
#[cfg(feature = "host")]
use crate::scenarios::SweepOutcome;
use crate::scenarios::{SweepGrid, SweepOptions, SweepReport};
use crate::site::compose::run_site_inner;
use crate::site::sweep::site_sweep_prepared_sink;
#[cfg(feature = "host")]
use crate::site::sweep::site_sweep_checkpointed_prepared;
#[cfg(feature = "host")]
use crate::site::SiteSweepOutcome;
use crate::site::{
    prepare_site, sweep_summary_csv, SiteGrid, SiteOptions, SiteReport, SiteSpec, SiteVariant,
};
use crate::aggregate::ScaleConfig;
use crate::util::json::{self, Json};
use crate::util::threadpool::Executor;
use anyhow::{bail, ensure, Context, Result};
#[cfg(feature = "host")]
use std::path::Path;

/// The four run families, as the wire-level kind tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunKind {
    /// One facility scenario (a degenerate one-cell sweep).
    Facility,
    /// A scenario sweep grid.
    Sweep,
    /// One multi-facility site.
    Site,
    /// A site sweep grid.
    SiteSweep,
}

impl RunKind {
    pub fn as_str(self) -> &'static str {
        match self {
            RunKind::Facility => "facility",
            RunKind::Sweep => "sweep",
            RunKind::Site => "site",
            RunKind::SiteSweep => "site_sweep",
        }
    }

    pub fn from_str(s: &str) -> Result<RunKind> {
        Ok(match s {
            "facility" => RunKind::Facility,
            "sweep" => RunKind::Sweep,
            "site" => RunKind::Site,
            "site_sweep" => RunKind::SiteSweep,
            other => bail!("unknown run kind '{other}' (facility|sweep|site|site_sweep)"),
        })
    }
}

/// *What* to run: the kind-tagged union of the four spec types. The JSON
/// envelope `{"kind": ..., "spec": {...}}` nests each spec's existing
/// file schema unchanged, so any scenario/grid/site file a planner
/// already has becomes a valid request body by wrapping it.
#[derive(Debug, Clone)]
pub enum RunSpec {
    Facility(ScenarioSpec),
    Sweep(SweepGrid),
    Site(SiteSpec),
    SiteSweep(SiteGrid),
}

impl RunSpec {
    pub fn kind(&self) -> RunKind {
        match self {
            RunSpec::Facility(_) => RunKind::Facility,
            RunSpec::Sweep(_) => RunKind::Sweep,
            RunSpec::Site(_) => RunKind::Site,
            RunSpec::SiteSweep(_) => RunKind::SiteSweep,
        }
    }

    /// Human-facing run name (specs without one report their kind).
    pub fn name(&self) -> String {
        match self {
            RunSpec::Facility(_) => "facility".to_string(),
            RunSpec::Sweep(g) => g.name.clone(),
            RunSpec::Site(s) => s.name.clone(),
            RunSpec::SiteSweep(g) => g.name.clone(),
        }
    }

    /// Unique configuration ids this run actually uses, in first-use
    /// order — the set [`prepare`] warms and a synthetic store must cover.
    pub fn config_ids(&self) -> Vec<String> {
        match self {
            RunSpec::Facility(s) => s.server_config.config_ids_used(&s.topology),
            RunSpec::Sweep(g) => grid_config_ids_used(g),
            RunSpec::Site(s) => s.config_ids(),
            RunSpec::SiteSweep(g) => g.base.config_ids(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            // Scenario files validate at parse time; re-check the two
            // invariants here so programmatically-built specs get the
            // same gate.
            RunSpec::Facility(s) => {
                if s.horizon_s <= 0.0 {
                    bail!("facility: horizon_s must be positive");
                }
                if s.pue < 1.0 {
                    bail!("facility: pue must be >= 1.0 (got {})", s.pue);
                }
                Ok(())
            }
            RunSpec::Sweep(g) => g.validate(),
            RunSpec::Site(s) => s.validate(),
            RunSpec::SiteSweep(g) => g.validate(),
        }
    }

    /// `{"kind": ..., "spec": {...}}`.
    pub fn to_json(&self) -> Json {
        let spec = match self {
            RunSpec::Facility(s) => s.to_json(),
            RunSpec::Sweep(g) => g.to_json(),
            RunSpec::Site(s) => s.to_json(),
            RunSpec::SiteSweep(g) => g.to_json(),
        };
        json::obj([("kind", Json::Str(self.kind().as_str().to_string())), ("spec", spec)])
    }

    pub fn from_json(v: &Json) -> Result<RunSpec> {
        let kind = RunKind::from_str(&v.str_field("kind")?)?;
        Self::from_kind_json(kind, v.get("spec")?)
    }

    /// Parse the bare spec object under an already-known kind.
    pub fn from_kind_json(kind: RunKind, spec: &Json) -> Result<RunSpec> {
        Ok(match kind {
            RunKind::Facility => {
                RunSpec::Facility(ScenarioSpec::from_json(spec).context("facility spec")?)
            }
            RunKind::Sweep => RunSpec::Sweep(SweepGrid::from_json(spec).context("sweep grid")?),
            RunKind::Site => RunSpec::Site(SiteSpec::from_json(spec).context("site spec")?),
            RunKind::SiteSweep => {
                RunSpec::SiteSweep(SiteGrid::from_json(spec).context("site sweep grid")?)
            }
        })
    }
}

/// The one-cell grid a facility run executes as: expansion reproduces the
/// scenario exactly (every [`ScenarioSpec`] field is either a grid
/// default or an axis value), with stable cell id `w0-t0-f0-s<seed>`.
fn facility_grid(spec: &ScenarioSpec) -> SweepGrid {
    SweepGrid {
        name: "facility".to_string(),
        defaults: GridDefaults {
            dataset: spec.dataset.clone(),
            horizon_s: spec.horizon_s,
            p_base_w: spec.p_base_w,
            pue: spec.pue,
        },
        workloads: vec![spec.workload.clone()],
        topologies: vec![spec.topology],
        fleets: vec![spec.server_config.clone()],
        seeds: vec![spec.seed],
    }
}

/// *How* to run: the merged [`SweepOptions`] + [`SiteOptions`] knob set.
///
/// Identity-irrelevant fields (worker counts, batch width, window size,
/// executor, retry policy) stay out of manifest identity hashes — the
/// conversions delegate to the legacy structs' `identity_json`, whose
/// field sets are pinned by a unit test below.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Generation sample interval (s). Facility/sweep default 0.25 (the
    /// paper's 250 ms); site kinds default 1.0.
    pub dt_s: f64,
    /// Ramp-measurement interval for summary stats (s).
    pub ramp_interval_s: f64,
    /// Streaming window (s). 0 = buffered for facility/sweep; site kinds
    /// always stream and default to 3600.
    pub window_s: f64,
    /// Outer fan-out workers (sweep cells / site facility budget);
    /// 0 = auto.
    pub workers: usize,
    /// Worker threads inside each scenario (facility/sweep only);
    /// 0 = auto.
    pub server_workers: usize,
    /// Servers per batched classifier call (0 = default, 1 = sequential).
    pub max_batch: usize,
    /// Export intervals per aggregation level (facility/sweep only).
    pub scales: ScaleConfig,
    /// `site_load.csv` export interval (site kinds only).
    pub load_interval_s: f64,
    /// Retain the composed site series on the report (site kinds; O(T)).
    pub collect_series: bool,
    /// Threaded (host default) or sequential execution; byte-invariant.
    pub executor: Executor,
    /// Checkpointed runs: re-runs after the first attempt per cell.
    pub max_retries: u32,
    /// Checkpointed runs: soft per-attempt wall-clock budget (s; 0 = off).
    pub cell_timeout_s: f64,
    /// Sweep kinds only: run just the cells/variants shard `i/N` owns (a
    /// deterministic partition by stable cell id — see [`crate::shard`]).
    /// Wire-settable (`"shard": "i/N"`), recorded in run manifests, and —
    /// like the worker knobs — excluded from manifest identity hashes, so
    /// every shard of a grid and `powertrace merge`'s assembled result
    /// share one content hash.
    pub shard: Option<crate::shard::Shard>,
}

impl RunOptions {
    /// The historical per-kind defaults: facility/sweep ran buffered at
    /// 250 ms, sites streamed hourly windows at 1 s.
    pub fn defaults_for(kind: RunKind) -> RunOptions {
        let site = matches!(kind, RunKind::Site | RunKind::SiteSweep);
        RunOptions {
            dt_s: if site { 1.0 } else { 0.25 },
            ramp_interval_s: 900.0,
            window_s: if site { 3600.0 } else { 0.0 },
            workers: 0,
            server_workers: 0,
            max_batch: 0,
            scales: ScaleConfig::default(),
            load_interval_s: 60.0,
            collect_series: false,
            executor: Executor::default(),
            max_retries: 1,
            cell_timeout_s: 0.0,
            shard: None,
        }
    }

    pub fn with_dt(mut self, dt_s: f64) -> Self {
        self.dt_s = dt_s;
        self
    }

    pub fn with_ramp_interval(mut self, s: f64) -> Self {
        self.ramp_interval_s = s;
        self
    }

    pub fn with_window(mut self, s: f64) -> Self {
        self.window_s = s;
        self
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn with_server_workers(mut self, n: usize) -> Self {
        self.server_workers = n;
        self
    }

    pub fn with_max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn with_scales(mut self, scales: ScaleConfig) -> Self {
        self.scales = scales;
        self
    }

    pub fn with_load_interval(mut self, s: f64) -> Self {
        self.load_interval_s = s;
        self
    }

    pub fn with_collect_series(mut self, yes: bool) -> Self {
        self.collect_series = yes;
        self
    }

    pub fn with_executor(mut self, executor: Executor) -> Self {
        self.executor = executor;
        self
    }

    pub fn with_max_retries(mut self, n: u32) -> Self {
        self.max_retries = n;
        self
    }

    pub fn with_cell_timeout(mut self, s: f64) -> Self {
        self.cell_timeout_s = s;
        self
    }

    pub fn with_shard(mut self, shard: Option<crate::shard::Shard>) -> Self {
        self.shard = shard;
        self
    }

    /// The sweep-engine view (facility and sweep kinds).
    pub(crate) fn to_sweep(&self) -> SweepOptions {
        SweepOptions {
            dt_s: self.dt_s,
            ramp_interval_s: self.ramp_interval_s,
            scenario_workers: self.workers,
            server_workers: self.server_workers,
            max_batch: self.max_batch,
            window_s: self.window_s,
            scales: self.scales.clone(),
            executor: self.executor,
            shard: self.shard,
        }
    }

    /// The site-engine view (site and site-sweep kinds).
    pub(crate) fn to_site(&self) -> SiteOptions {
        SiteOptions {
            dt_s: self.dt_s,
            window_s: self.window_s,
            workers: self.workers,
            max_batch: self.max_batch,
            ramp_interval_s: self.ramp_interval_s,
            load_interval_s: self.load_interval_s,
            collect_series: self.collect_series,
            executor: self.executor,
            shard: self.shard,
        }
    }

    /// The retry policy checkpointed execution runs under.
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy { max_retries: self.max_retries, cell_timeout_s: self.cell_timeout_s }
    }

    /// Parse the optional wire-level `options` object over the kind's
    /// defaults. Rejections are kind-aware and name the offending field:
    /// unknown keys are rejected (a typo silently reverting a knob to its
    /// default is the worst failure mode an options object can have), and
    /// so are knobs that exist but don't apply to this kind — e.g.
    /// `load_interval_s` on a `sweep` request. The executor is not
    /// wire-settable (requests run on the server's executor).
    pub fn from_json(kind: RunKind, v: Option<&Json>) -> Result<RunOptions> {
        let mut o = RunOptions::defaults_for(kind);
        let Some(v) = v else { return Ok(o) };
        let Json::Obj(map) = v else { bail!("options must be an object") };
        let site = matches!(kind, RunKind::Site | RunKind::SiteSweep);
        let sharded = matches!(kind, RunKind::Sweep | RunKind::SiteSweep);
        for key in map.keys() {
            let applies = match key.as_str() {
                // Every kind.
                "dt_s" | "ramp_interval_s" | "window_s" | "workers" | "max_batch"
                | "max_retries" | "cell_timeout_s" => true,
                // Facility/sweep engine knobs.
                "server_workers" | "scales" => !site,
                // Site composition knobs.
                "load_interval_s" | "collect_series" => site,
                // Only grid kinds have a cell list to partition.
                "shard" => sharded,
                other => bail!("options: unknown field '{other}' for kind '{}'", kind.as_str()),
            };
            if !applies {
                bail!("options: field '{key}' does not apply to kind '{}'", kind.as_str());
            }
        }
        if let Some(x) = v.get_opt("dt_s") {
            o.dt_s = x.as_f64()?;
            ensure!(
                o.dt_s.is_finite() && o.dt_s > 0.0,
                "options: field 'dt_s' on kind '{}' must be positive seconds (got {})",
                kind.as_str(),
                o.dt_s
            );
        }
        if let Some(x) = v.get_opt("ramp_interval_s") {
            o.ramp_interval_s = x.as_f64()?;
            ensure!(
                o.ramp_interval_s.is_finite() && o.ramp_interval_s > 0.0,
                "options: field 'ramp_interval_s' on kind '{}' must be positive seconds (got {})",
                kind.as_str(),
                o.ramp_interval_s
            );
        }
        if let Some(x) = v.get_opt("window_s") {
            o.window_s = x.as_f64()?;
            ensure!(
                o.window_s.is_finite() && o.window_s >= 0.0,
                "options: field 'window_s' on kind '{}' must be >= 0 seconds (got {})",
                kind.as_str(),
                o.window_s
            );
        }
        if let Some(x) = v.get_opt("workers") {
            o.workers = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("server_workers") {
            o.server_workers = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("max_batch") {
            o.max_batch = x.as_usize()?;
        }
        if let Some(s) = v.get_opt("scales") {
            if let Some(x) = s.get_opt("rack_interval_s") {
                o.scales.rack_interval_s = x.as_f64()?;
            }
            if let Some(x) = s.get_opt("row_interval_s") {
                o.scales.row_interval_s = x.as_f64()?;
            }
            if let Some(x) = s.get_opt("facility_intervals_s") {
                o.scales.facility_intervals_s = x.f64_array().map_err(anyhow::Error::from)?;
            }
        }
        if let Some(x) = v.get_opt("load_interval_s") {
            o.load_interval_s = x.as_f64()?;
            ensure!(
                o.load_interval_s.is_finite() && o.load_interval_s > 0.0,
                "options: field 'load_interval_s' on kind '{}' must be positive seconds (got {})",
                kind.as_str(),
                o.load_interval_s
            );
        }
        if let Some(x) = v.get_opt("collect_series") {
            o.collect_series = x.as_bool()?;
        }
        if let Some(x) = v.get_opt("max_retries") {
            o.max_retries = x.as_usize()? as u32;
        }
        if let Some(x) = v.get_opt("cell_timeout_s") {
            o.cell_timeout_s = x.as_f64()?;
            ensure!(
                o.cell_timeout_s.is_finite() && o.cell_timeout_s >= 0.0,
                "options: field 'cell_timeout_s' on kind '{}' must be >= 0 seconds (got {})",
                kind.as_str(),
                o.cell_timeout_s
            );
        }
        if let Some(x) = v.get_opt("shard") {
            let s = x.as_str()?;
            o.shard = Some(crate::shard::Shard::parse(s).with_context(|| {
                format!("options: field 'shard' on kind '{}'", kind.as_str())
            })?);
        }
        Ok(o)
    }

    /// The wire form [`RunOptions::from_json`] parses for `kind` —
    /// kind-aware like the parser, so only the fields that apply to the
    /// kind are emitted and the round trip through `from_json` is exact
    /// (executor omitted; it is not wire-settable).
    pub fn to_json(&self, kind: RunKind) -> Json {
        let site = matches!(kind, RunKind::Site | RunKind::SiteSweep);
        let mut fields = vec![
            ("dt_s", Json::Num(self.dt_s)),
            ("ramp_interval_s", Json::Num(self.ramp_interval_s)),
            ("window_s", Json::Num(self.window_s)),
            ("workers", Json::Num(self.workers as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("max_retries", Json::Num(self.max_retries as f64)),
            ("cell_timeout_s", Json::Num(self.cell_timeout_s)),
        ];
        if site {
            fields.push(("load_interval_s", Json::Num(self.load_interval_s)));
            fields.push(("collect_series", Json::Bool(self.collect_series)));
        } else {
            fields.push(("server_workers", Json::Num(self.server_workers as f64)));
            fields.push((
                "scales",
                json::obj([
                    ("rack_interval_s", Json::Num(self.scales.rack_interval_s)),
                    ("row_interval_s", Json::Num(self.scales.row_interval_s)),
                    ("facility_intervals_s", Json::from_f64s(&self.scales.facility_intervals_s)),
                ]),
            ));
        }
        if matches!(kind, RunKind::Sweep | RunKind::SiteSweep) {
            if let Some(sh) = self.shard {
                fields.push(("shard", Json::Str(sh.to_string())));
            }
        }
        json::obj(fields)
    }
}

/// One complete run request: what + how.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub spec: RunSpec,
    pub options: RunOptions,
}

impl RunRequest {
    /// A request with the kind's default options.
    pub fn new(spec: RunSpec) -> RunRequest {
        let options = RunOptions::defaults_for(spec.kind());
        RunRequest { spec, options }
    }

    /// The wire schema version this build speaks. Requests may omit `"v"`
    /// (treated as version 1); a request declaring any other version is
    /// rejected before parsing the spec — see docs/ARCHITECTURE.md
    /// §"Unified run API" for the compatibility rule.
    pub const WIRE_VERSION: u64 = 1;

    /// `{"v": 1, "kind": ..., "spec": {...}, "options": {...}}` — the wire
    /// body of `POST /v1/runs`. `v` and `options` are optional on parse.
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut o) = self.spec.to_json() else { unreachable!("spec is an object") };
        o.insert("v".to_string(), Json::Num(Self::WIRE_VERSION as f64));
        o.insert("options".to_string(), self.options.to_json(self.spec.kind()));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<RunRequest> {
        if let Some(x) = v.get_opt("v") {
            let ver = x.as_usize()? as u64;
            if ver != Self::WIRE_VERSION {
                bail!(
                    "unsupported RunRequest version {ver} (this build speaks v{})",
                    Self::WIRE_VERSION
                );
            }
        }
        let kind = RunKind::from_str(&v.str_field("kind")?)?;
        let spec = RunSpec::from_kind_json(kind, v.get("spec")?)?;
        let options = RunOptions::from_json(kind, v.get_opt("options"))?;
        Ok(RunRequest { spec, options })
    }
}

/// What [`execute`] hands back, by kind.
pub enum RunOutcome {
    Facility(SweepReport),
    Sweep(SweepReport),
    Site(SiteReport),
    SiteSweep(Vec<(SiteVariant, SiteReport)>),
}

impl RunOutcome {
    /// The run's summary CSV (the same bytes its sink export carries).
    pub fn summary_csv(&self) -> String {
        match self {
            RunOutcome::Facility(r) | RunOutcome::Sweep(r) => r.summary_csv(),
            RunOutcome::Site(r) => r.summary_csv(),
            RunOutcome::SiteSweep(results) => sweep_summary_csv(results),
        }
    }

    /// Human-readable table where the kind has one (falls back to CSV for
    /// site sweeps).
    pub fn summary_table(&self) -> String {
        match self {
            RunOutcome::Facility(r) | RunOutcome::Sweep(r) => r.summary_table(),
            RunOutcome::Site(r) => r.summary_table(),
            RunOutcome::SiteSweep(_) => self.summary_csv(),
        }
    }
}

/// Warm the generator for a spec: load + classify + pack every
/// configuration the run uses, exactly once. After this, [`execute_prepared`]
/// needs only `&Generator` — many runs can share one warm generator.
pub fn prepare(gen: &mut Generator, spec: &RunSpec) -> Result<()> {
    match spec {
        RunSpec::Facility(s) => gen.prepare_for(s),
        RunSpec::Sweep(g) => prepare_sweep(gen, g),
        RunSpec::Site(s) => prepare_site(gen, s),
        RunSpec::SiteSweep(g) => prepare_site(gen, &g.base),
    }
}

/// Validate, prepare, and execute one request. Exports (summary CSVs,
/// spec snapshots, streamed series) route through `sink` when given; the
/// layout matches what the historical per-kind `--out` directories held.
pub fn execute(
    gen: &mut Generator,
    req: &RunRequest,
    sink: Option<&dyn TraceSink>,
) -> Result<RunOutcome> {
    req.spec.validate()?;
    prepare(gen, &req.spec)?;
    execute_prepared(gen, req, sink)
}

/// [`execute`] over an already-[`prepare`]d shared generator.
pub fn execute_prepared(
    gen: &Generator,
    req: &RunRequest,
    sink: Option<&dyn TraceSink>,
) -> Result<RunOutcome> {
    req.spec.validate()?;
    match &req.spec {
        RunSpec::Facility(spec) => {
            let grid = facility_grid(spec);
            let report = sweep_prepared_sink(gen, &grid, &req.options.to_sweep(), sink)?;
            // The one-shot files (grid.json, summary.csv, per-cell
            // scenario.json + buffered series) complement whatever the
            // streaming path already sent through the sink.
            if let Some(s) = sink {
                report.write_sink(s)?;
            }
            Ok(RunOutcome::Facility(report))
        }
        RunSpec::Sweep(grid) => {
            let report = sweep_prepared_sink(gen, grid, &req.options.to_sweep(), sink)?;
            if let Some(s) = sink {
                report.write_sink(s)?;
            }
            Ok(RunOutcome::Sweep(report))
        }
        RunSpec::Site(spec) => {
            Ok(RunOutcome::Site(run_site_inner(gen, spec, &req.options.to_site(), sink, None)?))
        }
        RunSpec::SiteSweep(grid) => Ok(RunOutcome::SiteSweep(site_sweep_prepared_sink(
            gen,
            grid,
            &req.options.to_site(),
            sink,
        )?)),
    }
}

/// What [`execute_checkpointed`] hands back, by kind.
#[cfg(feature = "host")]
pub enum CheckpointedOutcome {
    Sweep(SweepOutcome),
    SiteSweep(SiteSweepOutcome),
}

#[cfg(feature = "host")]
impl CheckpointedOutcome {
    /// Cells/variants restored from the manifest without re-running.
    pub fn restored(&self) -> usize {
        match self {
            CheckpointedOutcome::Sweep(o) => o.restored,
            CheckpointedOutcome::SiteSweep(o) => o.restored,
        }
    }

    /// Cells/variants quarantined after exhausting the retry budget.
    pub fn failed(&self) -> &[crate::scenarios::QuarantinedCell] {
        match self {
            CheckpointedOutcome::Sweep(o) => &o.failed,
            CheckpointedOutcome::SiteSweep(o) => &o.failed,
        }
    }

    /// Cells/variants left pending by a cooperative shutdown.
    pub fn interrupted(&self) -> usize {
        match self {
            CheckpointedOutcome::Sweep(o) => o.interrupted,
            CheckpointedOutcome::SiteSweep(o) => o.interrupted,
        }
    }

    /// The final summary CSV bytes (restored + fresh rows, grid order).
    pub fn summary_csv(&self) -> &str {
        match self {
            CheckpointedOutcome::Sweep(o) => &o.summary_csv,
            CheckpointedOutcome::SiteSweep(o) => &o.summary_csv,
        }
    }

    pub fn manifest_path(&self) -> &Path {
        match self {
            CheckpointedOutcome::Sweep(o) => &o.manifest_path,
            CheckpointedOutcome::SiteSweep(o) => &o.manifest_path,
        }
    }
}

/// Crash-safe execution for the sweep kinds: a durable manifest under
/// `dir`, per-cell retry/quarantine isolation
/// ([`RunOptions::retry_policy`]), atomic exports, and `--resume`
/// convergence to the uninterrupted run's bytes. Facility and site runs
/// have no checkpointable cell structure and are rejected.
#[cfg(feature = "host")]
pub fn execute_checkpointed(
    gen: &mut Generator,
    req: &RunRequest,
    dir: &Path,
) -> Result<CheckpointedOutcome> {
    req.spec.validate()?;
    prepare(gen, &req.spec)?;
    execute_checkpointed_prepared(gen, req, dir)
}

/// [`execute_checkpointed`] over an already-[`prepare`]d shared generator.
#[cfg(feature = "host")]
pub fn execute_checkpointed_prepared(
    gen: &Generator,
    req: &RunRequest,
    dir: &Path,
) -> Result<CheckpointedOutcome> {
    let policy = req.options.retry_policy();
    match &req.spec {
        RunSpec::Sweep(grid) => Ok(CheckpointedOutcome::Sweep(sweep_checkpointed_prepared(
            gen,
            grid,
            &req.options.to_sweep(),
            dir,
            &policy,
        )?)),
        RunSpec::SiteSweep(grid) => {
            Ok(CheckpointedOutcome::SiteSweep(site_sweep_checkpointed_prepared(
                gen,
                grid,
                &req.options.to_site(),
                dir,
                &policy,
            )?))
        }
        other => bail!(
            "checkpointed execution supports sweep and site_sweep (got '{}')",
            other.kind().as_str()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::Topology;
    use crate::config::{ServerAssignment, WorkloadSpec};

    fn sweep_grid() -> SweepGrid {
        SweepGrid {
            name: "t".into(),
            defaults: GridDefaults::default(),
            workloads: vec![
                WorkloadSpec::Poisson { rate: 0.25 },
                WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
            ],
            topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 }],
            fleets: vec![
                ServerAssignment::Uniform("a".into()),
                ServerAssignment::PerRack(vec!["a".into(), "b".into()]),
            ],
            seeds: vec![0, 7],
        }
    }

    fn site_spec() -> SiteSpec {
        SiteSpec::staggered("tri", &ScenarioSpec::default_poisson("cfg", 0.5), 3, 0.0)
    }

    fn site_grid() -> SiteGrid {
        SiteGrid {
            name: "spread".into(),
            base: site_spec(),
            phase_spreads_h: vec![0.0, 3.0],
            seeds: vec![0, 7],
            battery_kwh: Vec::new(),
            cap_w: Vec::new(),
            battery: None,
        }
    }

    #[test]
    fn runspec_json_roundtrips_all_four_kinds() {
        let mut fac = ScenarioSpec::default_poisson("cfg", 0.5);
        fac.seed = 3;
        let specs = [
            RunSpec::Facility(fac.clone()),
            RunSpec::Sweep(sweep_grid()),
            RunSpec::Site(site_spec()),
            RunSpec::SiteSweep(site_grid()),
        ];
        for spec in specs {
            let j = spec.to_json();
            assert_eq!(j.str_field("kind").unwrap(), spec.kind().as_str());
            let back = RunSpec::from_json(&j).unwrap();
            assert_eq!(back.kind(), spec.kind());
            // The nested spec objects round-trip exactly.
            assert_eq!(json::to_string(&back.to_json()), json::to_string(&j));
            back.validate().unwrap();
        }
        // Tag-level errors are crisp.
        assert!(RunKind::from_str("mystery").is_err());
        let j = json::parse(r#"{"kind": "sweep", "spec": {"name": "x"}}"#).unwrap();
        assert!(RunSpec::from_json(&j).is_err());
    }

    #[test]
    fn runrequest_options_parse_over_kind_defaults() {
        // Absent options object → per-kind defaults.
        let fac = RunOptions::from_json(RunKind::Facility, None).unwrap();
        assert_eq!(fac.dt_s, 0.25);
        assert_eq!(fac.window_s, 0.0);
        let site = RunOptions::from_json(RunKind::Site, None).unwrap();
        assert_eq!(site.dt_s, 1.0);
        assert_eq!(site.window_s, 3600.0);
        assert_eq!(site.load_interval_s, 60.0);
        // Fields override defaults; the rest keep them.
        let v = json::parse(
            r#"{"dt_s": 0.5, "window_s": 120, "max_retries": 3,
                "scales": {"rack_interval_s": 2.0}}"#,
        )
        .unwrap();
        let o = RunOptions::from_json(RunKind::Sweep, Some(&v)).unwrap();
        assert_eq!(o.dt_s, 0.5);
        assert_eq!(o.window_s, 120.0);
        assert_eq!(o.max_retries, 3);
        assert_eq!(o.scales.rack_interval_s, 2.0);
        assert_eq!(o.scales.row_interval_s, 15.0);
        assert_eq!(o.ramp_interval_s, 900.0);
        // Unknown keys are rejected, not ignored.
        let v = json::parse(r#"{"dt": 0.5}"#).unwrap();
        assert!(RunOptions::from_json(RunKind::Sweep, Some(&v)).is_err());
        // And the wire form round-trips through from_json, for every kind.
        let o = RunOptions::defaults_for(RunKind::Site).with_dt(2.0).with_max_batch(4);
        let back = RunOptions::from_json(RunKind::Site, Some(&o.to_json(RunKind::Site))).unwrap();
        assert_eq!(back.dt_s, 2.0);
        assert_eq!(back.max_batch, 4);
        for kind in [RunKind::Facility, RunKind::Sweep, RunKind::Site, RunKind::SiteSweep] {
            let o = RunOptions::defaults_for(kind).with_max_retries(5);
            let back = RunOptions::from_json(kind, Some(&o.to_json(kind))).unwrap();
            assert_eq!(back.max_retries, 5);
        }
        // A sweep shard survives the round trip.
        let sh = crate::shard::Shard::parse("1/3").unwrap();
        let o = RunOptions::defaults_for(RunKind::Sweep).with_shard(Some(sh));
        let back = RunOptions::from_json(RunKind::Sweep, Some(&o.to_json(RunKind::Sweep))).unwrap();
        assert_eq!(back.shard, Some(sh));
    }

    /// Each kind-aware rejection path names the offending field AND the
    /// kind — a typo and a kind-mismatched knob read differently.
    #[test]
    fn options_rejections_name_field_and_kind() {
        let reject = |kind: RunKind, body: &str| -> String {
            let v = json::parse(body).unwrap();
            format!("{:#}", RunOptions::from_json(kind, Some(&v)).unwrap_err())
        };
        // Site-only knobs on sweep kinds.
        let e = reject(RunKind::Sweep, r#"{"load_interval_s": 60}"#);
        assert!(e.contains("'load_interval_s'") && e.contains("'sweep'"), "{e}");
        let e = reject(RunKind::Facility, r#"{"collect_series": true}"#);
        assert!(e.contains("'collect_series'") && e.contains("'facility'"), "{e}");
        // Sweep-engine knobs on site kinds.
        let e = reject(RunKind::Site, r#"{"server_workers": 2}"#);
        assert!(e.contains("'server_workers'") && e.contains("'site'"), "{e}");
        let e = reject(RunKind::SiteSweep, r#"{"scales": {}}"#);
        assert!(e.contains("'scales'") && e.contains("'site_sweep'"), "{e}");
        // Shards only make sense where there is a cell list to partition.
        let e = reject(RunKind::Facility, r#"{"shard": "0/3"}"#);
        assert!(e.contains("'shard'") && e.contains("'facility'"), "{e}");
        let e = reject(RunKind::Site, r#"{"shard": "0/3"}"#);
        assert!(e.contains("'shard'") && e.contains("'site'"), "{e}");
        // Unknown fields name the kind too.
        let e = reject(RunKind::Sweep, r#"{"dt": 0.5}"#);
        assert!(e.contains("'dt'") && e.contains("'sweep'"), "{e}");
        // Value validation: field + kind + offending value.
        let e = reject(RunKind::Sweep, r#"{"dt_s": 0}"#);
        assert!(e.contains("'dt_s'") && e.contains("'sweep'"), "{e}");
        let e = reject(RunKind::Facility, r#"{"ramp_interval_s": -1}"#);
        assert!(e.contains("'ramp_interval_s'") && e.contains("'facility'"), "{e}");
        let e = reject(RunKind::Sweep, r#"{"window_s": -5}"#);
        assert!(e.contains("'window_s'") && e.contains("'sweep'"), "{e}");
        let e = reject(RunKind::Site, r#"{"load_interval_s": 0}"#);
        assert!(e.contains("'load_interval_s'") && e.contains("'site'"), "{e}");
        let e = reject(RunKind::SiteSweep, r#"{"cell_timeout_s": -1}"#);
        assert!(e.contains("'cell_timeout_s'") && e.contains("'site_sweep'"), "{e}");
        // Malformed shard strings name the field through the context chain.
        let e = reject(RunKind::Sweep, r#"{"shard": "3/3"}"#);
        assert!(e.contains("'shard'") && e.contains("'sweep'"), "{e}");
        // The accepted forms still parse.
        let v = json::parse(r#"{"shard": "2/3", "window_s": 0}"#).unwrap();
        let o = RunOptions::from_json(RunKind::Sweep, Some(&v)).unwrap();
        assert_eq!(o.shard, Some(crate::shard::Shard { index: 2, count: 3 }));
    }

    #[test]
    fn runrequest_wire_version_gates_parsing() {
        let req = RunRequest::new(RunSpec::Sweep(sweep_grid()));
        let j = req.to_json();
        assert_eq!(j.get("v").unwrap().as_usize().unwrap(), 1);
        // v:1 and absent v both parse; any other version is rejected
        // before the spec is even looked at.
        RunRequest::from_json(&j).unwrap();
        let Json::Obj(mut o) = j.clone() else { unreachable!() };
        o.remove("v");
        RunRequest::from_json(&Json::Obj(o.clone())).unwrap();
        o.insert("v".to_string(), Json::Num(2.0));
        let e = format!("{:#}", RunRequest::from_json(&Json::Obj(o)).unwrap_err());
        assert!(e.contains("unsupported RunRequest version 2"), "{e}");

        // A sharded request round-trips with its shard intact.
        let mut req = RunRequest::new(RunSpec::Sweep(sweep_grid()));
        req.options.shard = Some(crate::shard::Shard::parse("0/2").unwrap());
        let back = RunRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.options.shard, req.options.shard);
    }

    #[test]
    fn facility_grid_expands_to_exactly_the_spec() {
        let mut spec = ScenarioSpec::default_poisson("cfg", 0.5);
        spec.seed = 3;
        spec.server_config = ServerAssignment::PerRack(vec!["a".into(), "b".into()]);
        spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
        spec.pue = 1.4;
        let grid = facility_grid(&spec);
        grid.validate().unwrap();
        let cells = grid.expand();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].id, "w0-t0-f0-s3");
        assert_eq!(cells[0].spec, spec);
        assert_eq!(
            RunSpec::Facility(spec.clone()).config_ids(),
            spec.server_config.config_ids_used(&spec.topology)
        );
    }

    /// The PR-7 identity rule, pinned: manifest hashes bind to exactly
    /// these fields, so execution-layout knobs can change across resumes
    /// without invalidating a checkpoint.
    #[test]
    fn manifest_identity_field_sets_are_pinned() {
        let o = RunOptions::defaults_for(RunKind::Sweep);
        let Json::Obj(m) = o.to_sweep().identity_json() else { panic!("identity is an object") };
        let keys: Vec<&str> = m.keys().map(String::as_str).collect();
        assert_eq!(keys, vec!["dt_s", "ramp_interval_s", "scales"]);
        let Json::Obj(m) = o.to_site().identity_json() else { panic!("identity is an object") };
        let keys: Vec<&str> = m.keys().map(String::as_str).collect();
        assert_eq!(keys, vec!["dt_s", "load_interval_s", "ramp_interval_s"]);
        // Identity-irrelevant knobs move nothing — including the shard, so
        // every shard of a grid (and the merged result) shares one
        // content hash with the unsharded run.
        let base = json::to_string(&o.to_sweep().identity_json());
        let tweaked = o
            .clone()
            .with_workers(7)
            .with_server_workers(3)
            .with_max_batch(2)
            .with_window(120.0)
            .with_executor(Executor::Sequential)
            .with_max_retries(9)
            .with_cell_timeout(5.0)
            .with_shard(Some(crate::shard::Shard { index: 1, count: 3 }));
        assert_eq!(json::to_string(&tweaked.to_sweep().identity_json()), base);
        let site_base = json::to_string(&o.to_site().identity_json());
        assert_eq!(json::to_string(&tweaked.to_site().identity_json()), site_base);
        // ...but the shard IS recorded in the manifest's launch options,
        // so a bare `--resume` re-runs the same slice.
        let rec = tweaked.to_sweep().record_json();
        assert_eq!(rec.get("shard").unwrap().as_str().unwrap(), "1/3");
        let rec = tweaked.to_site().record_json();
        assert_eq!(rec.get("shard").unwrap().as_str().unwrap(), "1/3");
        assert!(o.to_sweep().record_json().get_opt("shard").is_none());
        // Identity-relevant knobs do move it.
        assert_ne!(json::to_string(&o.clone().with_dt(0.5).to_sweep().identity_json()), base);
        assert_ne!(
            json::to_string(&o.clone().with_load_interval(300.0).to_site().identity_json()),
            site_base
        );
    }
}
