//! Minimal, dependency-free JSON value model, parser, and emitter.
//!
//! The offline registry cache contains only the `xla` crate closure, so
//! `serde_json` is unavailable (DESIGN.md §3). This module implements the
//! subset of JSON the project needs — which is all of RFC 8259 except for
//! `\u` surrogate-pair edge cases beyond the BMP being passed through as
//! replacement characters. It is used for the catalog, planner-facing
//! configs, per-configuration artifacts, and experiment outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so emission is
/// deterministic (stable diffs for generated artifacts).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error with a human-readable location/context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.msg)
    }
}

impl std::error::Error for JsonError {}

fn err<T>(msg: impl Into<String>) -> Result<T, JsonError> {
    Err(JsonError { msg: msg.into() })
}

// ---------------------------------------------------------------------------
// Accessors
// ---------------------------------------------------------------------------

impl Json {
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u32::MAX as f64 {
            return err(format!("expected non-negative integer, got {f}"));
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => err(format!("expected bool, got {}", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => err(format!("expected string, got {}", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => err(format!("expected array, got {}", other.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => err(format!("expected object, got {}", other.kind())),
        }
    }

    /// Object field access with a contextual error message.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self.as_obj()?.get(key) {
            Some(v) => Ok(v),
            None => err(format!("missing field '{key}'")),
        }
    }

    /// Optional field: `None` when absent or explicitly `null`.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => match o.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    pub fn f64_field(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)?
            .as_f64()
            .map_err(|e| JsonError { msg: format!("field '{key}': {}", e.msg) })
    }

    pub fn str_field(&self, key: &str) -> Result<String, JsonError> {
        Ok(self.get(key)?.as_str()?.to_string())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)?
            .as_usize()
            .map_err(|e| JsonError { msg: format!("field '{key}': {}", e.msg) })
    }

    /// Parse an array of numbers into `Vec<f64>`.
    pub fn f64_array(&self) -> Result<Vec<f64>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Parse an array of numbers into `Vec<f32>`.
    pub fn f32_array(&self) -> Result<Vec<f32>, JsonError> {
        Ok(self.f64_array()?.into_iter().map(|x| x as f32).collect())
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl Json {
    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn from_f32s(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

/// Convenience builder for objects: `obj([("a", 1.0.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(fields: I) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn fail<T>(&self, msg: &str) -> Result<T, JsonError> {
        // Report 1-based line/column for readability.
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        err(format!("{msg} at line {line} col {col}"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.fail(&format!("expected '{}'", c as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.fail(&format!("invalid literal (expected '{lit}')"))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > 128 {
            return self.fail("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            None => self.fail("unexpected end of input"),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => self.fail(&format!("unexpected character '{}'", c as char)),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.fail("expected ',' or ']'");
                }
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return self.fail("expected string key");
            }
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.fail("expected ',' or '}'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.fail("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs for completeness.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.peek() == Some(b'\\') {
                                self.pos += 1;
                                if self.bump() != Some(b'u') {
                                    return self.fail("expected low surrogate");
                                }
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                            } else {
                                out.push('\u{fffd}');
                            }
                        } else {
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                    }
                    _ => return self.fail("invalid escape"),
                },
                Some(b) if b < 0x20 => return self.fail("control character in string"),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return self.fail("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..start + len]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = start + len;
                            }
                            Err(_) => return self.fail("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.fail("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => return self.fail("invalid hex digit"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut saw_digit = false;
        while let Some(b'0'..=b'9') = self.peek() {
            self.pos += 1;
            saw_digit = true;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while let Some(b'0'..=b'9') = self.peek() {
                self.pos += 1;
                saw_digit = true;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            let mut exp_digit = false;
            while let Some(b'0'..=b'9') = self.peek() {
                self.pos += 1;
                exp_digit = true;
            }
            if !exp_digit {
                return self.fail("invalid exponent");
            }
        }
        if !saw_digit {
            return self.fail("invalid number");
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => self.fail("number out of range"),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed, nothing else).
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser::new(s);
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.fail("trailing data");
    }
    Ok(v)
}

/// Read and parse a JSON file.
#[cfg(feature = "host")]
pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
    let s = std::fs::read_to_string(path)
        .map_err(|e| JsonError { msg: format!("read {}: {e}", path.display()) })?;
    parse(&s).map_err(|e| JsonError { msg: format!("{}: {}", path.display(), e.msg) })
}

// ---------------------------------------------------------------------------
// Emitter
// ---------------------------------------------------------------------------

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null (callers should avoid this).
        out.push_str("null");
    } else if n == 0.0 && n.is_sign_negative() {
        out.push_str("-0.0"); // preserve the sign bit through round-trips
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Shortest round-trippable representation.
        out.push_str(&format!("{n}"));
    }
}

fn emit_value(out: &mut String, v: &Json, indent: Option<usize>, level: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => emit_num(out, *n),
        Json::Str(s) => emit_str(out, s),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            // Flat numeric arrays are emitted on one line regardless of indent.
            let flat = indent.is_none() || a.iter().all(|x| matches!(x, Json::Num(_)));
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if !flat {
                    newline(out, indent, level + 1);
                }
                emit_value(out, x, indent, level + 1);
            }
            if !flat {
                newline(out, indent, level);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, level + 1);
                emit_str(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                emit_value(out, x, indent, level + 1);
            }
            newline(out, indent, level);
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

/// Emit compact JSON.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    emit_value(&mut out, v, None, 0);
    out
}

/// Emit pretty-printed JSON with the given indent width.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    emit_value(&mut out, v, Some(1), 0);
    out.push('\n');
    out
}

/// Write pretty JSON to a file, creating parent directories. The write is
/// atomic: bytes are staged to a `<name>.tmp` sibling and renamed into
/// place, so a crash mid-write never leaves a truncated document behind —
/// specs, artifacts, and the sweep run manifest all rely on this.
#[cfg(feature = "host")]
pub fn write_file(path: &std::path::Path, v: &Json) -> Result<(), JsonError> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .map_err(|e| JsonError { msg: format!("mkdir {}: {e}", parent.display()) })?;
    }
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    std::fs::write(&tmp, to_string_pretty(v))
        .map_err(|e| JsonError { msg: format!("write {}: {e}", tmp.display()) })?;
    std::fs::rename(&tmp, path)
        .map_err(|e| JsonError { msg: format!("rename {}: {e}", path.display()) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("c").unwrap(), "x");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ✓");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "01x", "\"unterminated", "{\"a\":1,}",
            "[1] trailing", "nan", "+1", "1.e", "--2", "{a:1}", "\"\\q\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = obj([
            ("name", "trace".into()),
            ("vals", Json::from_f64s(&[1.0, 2.5, -3.25e-7])),
            ("nested", obj([("ok", true.into()), ("n", Json::Null)])),
        ]);
        for s in [to_string(&v), to_string_pretty(&v)] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_floats_exactly() {
        let vals = [0.1, 1.0 / 3.0, 1e-300, 1e300, -0.0, 123456789.123456];
        let v = Json::from_f64s(&vals);
        let back = parse(&to_string(&v)).unwrap().f64_array().unwrap();
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(-1.0)), "-1");
    }

    #[test]
    fn accessor_errors_are_contextual() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let e = v.get("b").unwrap_err();
        assert!(e.msg.contains("'b'"));
        let e = v.get("a").unwrap().as_str().unwrap_err();
        assert!(e.msg.contains("expected string"));
    }

    #[test]
    fn as_usize_validates() {
        assert_eq!(parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn get_opt_handles_null_and_missing() {
        let v = parse(r#"{"a": null, "b": 1}"#).unwrap();
        assert!(v.get_opt("a").is_none());
        assert!(v.get_opt("missing").is_none());
        assert!(v.get_opt("b").is_some());
    }

    #[test]
    fn f32_array_roundtrip() {
        let v = Json::from_f32s(&[1.5f32, -2.25, 0.0]);
        assert_eq!(v.f32_array().unwrap(), vec![1.5f32, -2.25, 0.0]);
    }
}
