//! Minimal scoped parallel-map used by the coordinator to fan server-trace
//! generation across cores (tokio/rayon unavailable offline), behind the
//! [`Executor`] seam of the core/host split.
//!
//! `parallel_map` preserves input order in its output and propagates panics
//! (one bad item tears down the batch — right for the tightly-coupled
//! server fan-out inside a single cell). `parallel_map_results` is the
//! fault-isolating variant for independent items (sweep cells): each
//! item's panic or error lands in its own `Result` slot and every other
//! item still completes.
//!
//! Without the `host` feature there are no threads at all: every entry
//! point runs items sequentially on the caller thread. That fallback is
//! bit-identical to the threaded path by construction — results land in
//! input order either way, and every aggregation fold in the crate is
//! already index-ordered rather than completion-ordered.

use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(feature = "host")]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(feature = "host")]
use std::sync::Mutex;

/// How fan-out sections run: on a scoped thread pool (host) or inline on
/// the caller thread (the only option in a core-only build, and a
/// debugging/embedding choice on hosts). Exports are bit-identical either
/// way; `Sequential` trades wall-clock for zero thread dependence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// Run every item on the caller thread, in index order.
    Sequential,
    /// Scoped worker threads with dynamic work distribution.
    #[cfg(feature = "host")]
    Threaded,
}

impl Default for Executor {
    fn default() -> Executor {
        Executor::host_default()
    }
}

impl Executor {
    /// The richest executor this build supports: `Threaded` with `host`,
    /// `Sequential` otherwise.
    pub fn host_default() -> Executor {
        #[cfg(feature = "host")]
        {
            Executor::Threaded
        }
        #[cfg(not(feature = "host"))]
        {
            Executor::Sequential
        }
    }

    pub fn is_sequential(&self) -> bool {
        matches!(self, Executor::Sequential)
    }

    /// The worker count fan-out sections should use under this executor:
    /// `requested` (already defaulted/clamped by the caller) when
    /// threaded, 1 when sequential.
    pub fn workers(&self, requested: usize) -> usize {
        if self.is_sequential() {
            1
        } else {
            requested
        }
    }

    /// [`parallel_map`] under this executor's worker policy.
    pub fn map<T, F>(&self, n: usize, workers: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        parallel_map(n, self.workers(workers), f)
    }

    /// [`parallel_map_results`] under this executor's worker policy.
    pub fn map_results<T, F>(&self, n: usize, workers: usize, f: F) -> Vec<anyhow::Result<T>>
    where
        T: Send,
        F: Fn(usize) -> anyhow::Result<T> + Sync,
    {
        parallel_map_results(n, self.workers(workers), f)
    }
}

/// Number of worker threads to use by default: all cores, capped at 16
/// (beyond that the PJRT CPU client contends with itself). Core-only
/// builds have no threads, so the default is 1.
pub fn default_workers() -> usize {
    #[cfg(feature = "host")]
    {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }
    #[cfg(not(feature = "host"))]
    {
        1
    }
}

/// Apply `f` to `0..n` on `workers` threads, collecting results in order.
/// Work is distributed dynamically (atomic counter) so uneven item costs —
/// e.g. servers with different trace lengths — balance automatically.
/// `workers <= 1` (and every core-only build) runs on the caller thread.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    #[cfg(feature = "host")]
    {
        let next = AtomicUsize::new(0);
        let out: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    out.lock().unwrap()[i] = Some(v);
                });
            }
        });
        out.into_inner().unwrap().into_iter().map(|v| v.expect("worker completed")).collect()
    }
    #[cfg(not(feature = "host"))]
    {
        (0..n).map(f).collect()
    }
}

/// Render a panic payload (from `catch_unwind` / `JoinHandle::join`) as a
/// readable message. Payloads are almost always `&str` or `String`.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string payload>".to_string()
    }
}

/// Like [`parallel_map`], but each item is fault-isolated: `f`'s errors are
/// returned in place, and a panicking item is caught and surfaced as an
/// `Err` carrying the panic message instead of unwinding through the pool.
/// Output order matches input order. Items never see each other's failures.
pub fn parallel_map_results<T, F>(n: usize, workers: usize, f: F) -> Vec<anyhow::Result<T>>
where
    T: Send,
    F: Fn(usize) -> anyhow::Result<T> + Sync,
{
    let call = |i: usize| -> anyhow::Result<T> {
        match catch_unwind(AssertUnwindSafe(|| f(i))) {
            Ok(r) => r,
            Err(p) => Err(anyhow::anyhow!("worker panicked: {}", panic_message(&*p))),
        }
    };
    parallel_map(n, workers, call)
}

/// Fold items `0..n` in parallel into per-worker accumulators, then reduce.
/// Used for streaming facility aggregation where materializing every
/// server trace at once would be wasteful. A single worker (and every
/// core-only build) folds `0..n` in order into one accumulator on the
/// caller thread — the same fold order one spawned worker would see, so
/// the result is bit-identical.
pub fn parallel_fold<A, F, R>(n: usize, workers: usize, init: impl Fn() -> A + Sync, fold: F, reduce: R) -> A
where
    A: Send,
    F: Fn(&mut A, usize) + Sync,
    R: Fn(A, A) -> A,
{
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        let mut acc = init();
        for i in 0..n {
            fold(&mut acc, i);
        }
        return acc;
    }
    #[cfg(feature = "host")]
    {
        let next = AtomicUsize::new(0);
        let accs: Mutex<Vec<A>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut acc = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        fold(&mut acc, i);
                    }
                    accs.lock().unwrap().push(acc);
                });
            }
        });
        let mut accs = accs.into_inner().unwrap();
        let mut total = accs.pop().unwrap_or_else(&init);
        for a in accs {
            total = reduce(total, a);
        }
        total
    }
    #[cfg(not(feature = "host"))]
    {
        let _ = &reduce;
        let mut acc = init();
        for i in 0..n {
            fold(&mut acc, i);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(3, 64, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn fold_sums_correctly() {
        let total = parallel_fold(
            1000,
            8,
            || 0u64,
            |acc, i| *acc += i as u64,
            |a, b| a + b,
        );
        assert_eq!(total, (0..1000u64).sum());
    }

    #[test]
    fn fold_single_worker_runs_on_caller_thread() {
        let total = parallel_fold(100, 1, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn fold_vector_accumulators() {
        // Sum 10 one-hot vectors elementwise — mirrors rack aggregation.
        let total = parallel_fold(
            10,
            4,
            || vec![0.0f64; 10],
            |acc, i| acc[i] += 1.0,
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
        );
        assert_eq!(total, vec![1.0; 10]);
    }

    #[test]
    fn executor_worker_policy() {
        assert_eq!(Executor::Sequential.workers(8), 1);
        assert!(Executor::Sequential.is_sequential());
        #[cfg(feature = "host")]
        {
            assert_eq!(Executor::Threaded.workers(8), 8);
            assert_eq!(Executor::host_default(), Executor::Threaded);
        }
        #[cfg(not(feature = "host"))]
        assert_eq!(Executor::host_default(), Executor::Sequential);
        let seq = Executor::Sequential.map(5, 8, |i| i * 2);
        assert_eq!(seq, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    #[should_panic]
    fn propagates_panics() {
        parallel_map(10, 4, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn results_isolate_panics_and_errors_per_item() {
        let out = parallel_map_results(10, 4, |i| {
            if i == 3 {
                anyhow::bail!("bad item");
            }
            if i == 5 {
                panic!("boom {i}");
            }
            Ok(i * 10)
        });
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            match (i, r) {
                (3, Err(e)) => assert!(format!("{e}").contains("bad item")),
                (5, Err(e)) => {
                    let msg = format!("{e}");
                    assert!(msg.contains("worker panicked") && msg.contains("boom 5"), "{msg}");
                }
                (_, Ok(v)) => assert_eq!(*v, i * 10),
                (_, Err(e)) => panic!("item {i} unexpectedly failed: {e}"),
            }
        }
    }

    #[test]
    fn results_single_worker_catches_too() {
        // workers == 1 runs on the caller thread; the catch must still hold.
        let out = parallel_map_results(2, 1, |i| {
            if i == 0 {
                panic!("caller-thread panic");
            }
            Ok(i)
        });
        assert!(out[0].is_err());
        assert_eq!(out[1].as_ref().unwrap(), &1);
    }
}
