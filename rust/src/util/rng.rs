//! Deterministic pseudo-random number generation and the distribution draws
//! used throughout the pipeline (normal, lognormal, exponential, Poisson,
//! categorical).
//!
//! Implements xoshiro256++ (Blackman & Vigna) seeded through SplitMix64, so
//! every experiment is reproducible from a single `u64` seed and independent
//! per-server streams can be forked cheaply.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce four
        // zeros from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task (e.g. one server).
    /// Mixing the label through SplitMix64 decorrelates nearby indices.
    pub fn fork(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias is negligible for n ≪ 2^64 but we use widening multiply.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Open-interval u1 to avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count with the given mean (Knuth for small means,
    /// normal approximation above 64 where the difference is negligible for
    /// our workloads).
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let v = self.normal_ms(mean, mean.sqrt()).round();
            return v.max(0.0) as usize;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 4096 {
                return k; // numerically impossible; guard against p underflow
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return 0;
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_differ_and_are_stable() {
        let base = Rng::new(7);
        let mut f1 = base.fork(0);
        let mut f2 = base.fork(1);
        let mut f1b = base.fork(0);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (m, v) = stats(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!((v - 1.0 / 12.0).abs() < 0.005, "var {v}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let (m, v) = stats(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.exponential(2.0)).collect();
        let (m, _) = stats(&xs);
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn poisson_moments_small_and_large() {
        let mut r = Rng::new(4);
        for mean in [0.5, 4.0, 120.0] {
            let xs: Vec<f64> = (0..20_000).map(|_| r.poisson(mean) as f64).collect();
            let (m, v) = stats(&xs);
            assert!((m - mean).abs() < 0.05 * mean.max(1.0), "mean {m} vs {mean}");
            assert!((v - mean).abs() < 0.12 * mean.max(1.0), "var {v} vs {mean}");
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(5);
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.lognormal(1.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 1f64.exp()).abs() < 0.1, "median {med}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(6);
        let w = [1.0f32, 3.0, 0.0, 6.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[2], 0);
        let f1 = counts[1] as f64 / 40_000.0;
        let f3 = counts[3] as f64 / 40_000.0;
        assert!((f1 - 0.3).abs() < 0.02);
        assert!((f3 - 0.6).abs() < 0.02);
    }

    #[test]
    fn categorical_degenerate_weights() {
        let mut r = Rng::new(7);
        assert_eq!(r.categorical(&[0.0, 0.0]), 0);
        assert_eq!(r.categorical(&[-1.0, 0.0, 2.0]), 2);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        let xs: Vec<f64> = (0..30_000).map(|_| r.below(10) as f64).collect();
        let (m, _) = stats(&xs);
        assert!((m - 4.5).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
