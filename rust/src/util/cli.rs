//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `command [subcommand] --flag value --bool-flag positional...`
//! with typed accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: flags (`--name value` / `--name`), and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
    pub positional: Vec<String>,
}

/// Declared option for usage text.
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse a raw argv tail. Flags may be `--k v` or `--k=v`; a flag followed
    /// by another flag (or end of input) is treated as boolean.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let items: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    out.bools.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{s}'")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an integer, got '{s}'")),
        }
    }

    /// Comma-separated list of floats, e.g. `--rates 0.25,0.5,1`.
    pub fn f64_list(&self, name: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad number '{p}'"))
                })
                .collect(),
        }
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, summary: &str, opts: &[Opt]) -> String {
    let mut s = format!("usage: powertrace {cmd} [options]\n  {summary}\n\noptions:\n");
    for o in opts {
        let d = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
        s.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, d));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_flag_value_pairs() {
        let a = parse(&["--rate", "0.5", "--out", "x.json"]);
        assert_eq!(a.str_opt("rate"), Some("0.5"));
        assert_eq!(a.str_or("out", "y"), "x.json");
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["--rate=2.5", "--name=a b"]);
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.str_opt("name"), Some("a b"));
    }

    #[test]
    fn boolean_flags_and_positionals() {
        let a = parse(&["table1", "--verbose", "--seed", "7", "extra"]);
        assert_eq!(a.positional, vec!["table1", "extra"]);
        assert!(a.has("verbose"));
        assert!(a.has("seed"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse(&["--fast"]);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.f64_or("n", 1.0).is_err());
        assert_eq!(a.usize_or("m", 3).unwrap(), 3);
    }

    #[test]
    fn f64_list_parsing() {
        let a = parse(&["--rates", "0.25, 0.5,1"]);
        assert_eq!(a.f64_list("rates", &[]).unwrap(), vec![0.25, 0.5, 1.0]);
        assert_eq!(a.f64_list("other", &[2.0]).unwrap(), vec![2.0]);
        let bad = parse(&["--rates", "1,x"]);
        assert!(bad.f64_list("rates", &[]).is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "generate",
            "generate a server trace",
            &[Opt { name: "rate", help: "arrival rate", default: Some("0.5") }],
        );
        assert!(u.contains("--rate"));
        assert!(u.contains("default: 0.5"));
    }
}
