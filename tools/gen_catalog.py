#!/usr/bin/env python3
"""Generate data/catalog.json — the single-source configuration catalog.

The catalog is read by BOTH the Python build path (testbed campaign, training)
and the Rust runtime (testbed mirror, baselines, experiments), so the
"ground-truth" testbed parameterization lives in exactly one place.

The *truth* block per configuration parameterizes the synthetic testbed that
stands in for the paper's Azure DGX measurement campaign (DESIGN.md §3):
latency laws (power-law TTFT, occupancy-dependent TBT) and the GPU power law
(idle → saturating decode occupancy curve → near-TDP prefill, plus noise;
MoE adds hidden AR(1) expert-routing noise). These deliberately differ in
functional form from the paper's *surrogate* (log-linear TTFT, lognormal TBT)
so that calibration is a genuine fit, as in the paper.
"""
import json
import math
import sys

GPUS = {
    "a100": {"tdp_w": 400.0, "idle_w": 55.0, "perf": 1.0, "name": "NVIDIA A100 80GB"},
    "h100": {"tdp_w": 700.0, "idle_w": 70.0, "perf": 1.8, "name": "NVIDIA H100 80GB"},
}

# params_b: total parameters (billions); active_b: activated per token (MoE).
MODELS = {
    "llama8b":    {"name": "Llama-3.1 (8B)",             "params_b": 8.0,   "active_b": 8.0,   "kind": "dense", "reasoning": False},
    "llama70b":   {"name": "Llama-3.1 (70B)",            "params_b": 70.0,  "active_b": 70.0,  "kind": "dense", "reasoning": False},
    "llama405b":  {"name": "Llama-3.1 (405B)",           "params_b": 405.0, "active_b": 405.0, "kind": "dense", "reasoning": False},
    "r1d8b":      {"name": "DeepSeek-R1-Distill (8B)",   "params_b": 8.0,   "active_b": 8.0,   "kind": "dense", "reasoning": True},
    "r1d70b":     {"name": "DeepSeek-R1-Distill (70B)",  "params_b": 70.0,  "active_b": 70.0,  "kind": "dense", "reasoning": True},
    "gptoss20b":  {"name": "gpt-oss (20B)",              "params_b": 20.0,  "active_b": 3.6,   "kind": "moe",   "reasoning": True},
    "gptoss120b": {"name": "gpt-oss (120B)",             "params_b": 120.0, "active_b": 5.1,   "kind": "moe",   "reasoning": True},
}

# The measured campaign matrix (model, gpu, tp). Chosen to cover the paper's
# Table 1 aggregation (every model, >=1 config; dense flagships get several)
# plus the specific configs named in figures (Fig1 70B/TP8/A100; Fig3 8B/H100;
# Fig5 r1d8b/H100/TP8; Fig6 8B/A100/TP2 + gptoss120b/A100/TP4; Fig13 r1d70b).
CONFIGS = [
    ("llama8b", "a100", 2),
    ("llama8b", "h100", 1),
    ("llama70b", "a100", 4),
    ("llama70b", "a100", 8),
    ("llama70b", "h100", 4),
    ("llama70b", "h100", 8),
    ("llama405b", "h100", 8),
    ("r1d8b", "a100", 2),
    ("r1d8b", "h100", 8),
    ("r1d70b", "a100", 8),
    ("r1d70b", "h100", 4),
    ("gptoss20b", "a100", 2),
    ("gptoss120b", "a100", 4),
    ("gptoss120b", "h100", 4),
]

# Request length profiles standing in for the paper's four prompt datasets
# (ShareGPT, InstructCoder, AIMO-AIME, Edit-10K-Char). Lognormal in tokens.
DATASETS = {
    "sharegpt":     {"in_median": 220.0,  "in_sigma": 0.9, "out_median": 180.0, "out_sigma": 0.8},
    "instructcoder": {"in_median": 512.0, "in_sigma": 0.7, "out_median": 256.0, "out_sigma": 0.7},
    "aime":         {"in_median": 350.0,  "in_sigma": 0.5, "out_median": 900.0, "out_sigma": 0.9},
    "edit10k":      {"in_median": 2000.0, "in_sigma": 0.4, "out_median": 300.0, "out_sigma": 0.6},
}


def truth_params(model_key, gpu_key, tp):
    m, g = MODELS[model_key], GPUS[gpu_key]
    b = m["active_b"]
    perf = g["perf"]
    # --- latency laws (testbed ground truth) ---
    # single-stream inter-token latency, seconds/token
    tbt0 = 0.006 * (b / 8.0) ** 0.8 / (tp ** 0.85 * perf)
    # TTFT power law: ttft = c_pre * (n_in/512)^gamma_pre  (seconds)
    c_pre = 0.25 * (m["params_b"] / 8.0) ** 0.9 / (tp ** 0.9 * perf)
    # --- power law (per active GPU, fractions of TDP span) ---
    dec_max = 0.55 + 0.02 * math.log10(m["params_b"])
    truth = {
        "tbt0_s": round(tbt0, 6),
        # Occupancy-interference slopes: mild, as in production serving
        # (vLLM's continuous batching hides most batch-size latency cost);
        # also what keeps the paper's pooled log-linear surrogate (Eq. 4-5)
        # a faithful fit across arrival rates, matching their Fig. 5.
        "kappa_dec": 0.5,          # TBT multiplier slope with batch occupancy
        "c_pre_s": round(c_pre, 6),
        "gamma_pre": 1.15,         # superlinear TTFT exponent
        "kappa_pre": 0.25,         # prefill interference with batch occupancy
        "a0": 10.0,                # decode occupancy saturation constant
        "dec_min_frac": 0.35,      # utilization at A=1 (decode only)
        "dec_max_frac": round(dec_max, 4),
        "pre_frac": 0.88,          # prefill-present utilization level
        "mixed_bonus_frac": 0.04,  # extra when prefill overlaps a busy batch
        "noise_w": round(0.015 * g["tdp_w"], 3),   # white per-GPU power noise
        "meas_noise_w": 3.0,       # nvidia-smi 250 ms sampling noise (server)
    }
    if m["kind"] == "moe":
        truth["ar_phi"] = 0.85                      # hidden expert-routing noise
        truth["ar_sigma_w"] = round(0.05 * g["tdp_w"], 3)
    else:
        truth["ar_phi"] = 0.0
        truth["ar_sigma_w"] = 0.0
    return truth


def main(out_path):
    configs = []
    for model_key, gpu_key, tp in CONFIGS:
        cid = f"{model_key}_{gpu_key}_tp{tp}"
        configs.append({
            "id": cid,
            "model": model_key,
            "gpu": gpu_key,
            "tp": tp,
            "n_gpus_server": 8,
            "truth": truth_params(model_key, gpu_key, tp),
        })
    catalog = {
        "version": 1,
        "gpus": GPUS,
        "models": MODELS,
        "datasets": DATASETS,
        "configs": configs,
        "campaign": {
            # arrival rates (req/s) as in the paper's sweep 0.125..4
            "rates": [0.125, 0.25, 0.5, 1.0, 2.0, 4.0],
            "reps": 4,
            "trace_seconds": 480.0,
            "dt_s": 0.25,
            "max_batch": 64,
            "reasoning_out_mult": 2.0,
        },
        "site": {
            "p_base_w": 1000.0,   # non-GPU IT power per server (paper §3.4)
            "pue": 1.3,
        },
    }
    with open(out_path, "w") as f:
        json.dump(catalog, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}: {len(configs)} configs")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "data/catalog.json")
