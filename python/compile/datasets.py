"""Request-length sampling and Poisson arrival schedules for the synthetic
measurement campaign (mirror of `rust/src/workload/`)."""

import numpy as np

from .catalog import Catalog, DatasetProfile, ServerConfig


def sample_lengths(profile: DatasetProfile, out_mult: float, n: int, rng: np.random.Generator):
    """Lognormal token lengths; (n_in, n_out) arrays of ints >= 1."""
    n_in = np.exp(rng.normal(np.log(profile.in_median), profile.in_sigma, size=n))
    n_out = np.exp(rng.normal(np.log(profile.out_median), profile.out_sigma, size=n)) * out_mult
    n_in = np.clip(np.round(n_in), 1, 32_768).astype(np.int64)
    n_out = np.clip(np.round(n_out), 1, 16_384).astype(np.int64)
    return n_in, n_out


def poisson_schedule(rate: float, horizon_s: float, profile: DatasetProfile,
                     out_mult: float, rng: np.random.Generator):
    """Poisson(rate) arrivals over [0, horizon): list of (t, n_in, n_out)."""
    ts = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= horizon_s:
            break
        ts.append(t)
    n = len(ts)
    n_in, n_out = sample_lengths(profile, out_mult, n, rng)
    return [
        {"t": float(ts[i]), "n_in": int(n_in[i]), "n_out": int(n_out[i])}
        for i in range(n)
    ]


def out_mult_for(cat: Catalog, cfg: ServerConfig) -> float:
    return cat.campaign.reasoning_out_mult if cat.model_of(cfg).reasoning else 1.0
