"""Catalog loader — reads the shared `data/catalog.json` (single source of
truth with the Rust side; see DESIGN.md §3)."""

import json
import os
from dataclasses import dataclass
from typing import Dict, List


def repo_root() -> str:
    env = os.environ.get("POWERTRACE_ROOT")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", ".."))


@dataclass(frozen=True)
class Gpu:
    key: str
    name: str
    tdp_w: float
    idle_w: float
    perf: float


@dataclass(frozen=True)
class Model:
    key: str
    name: str
    params_b: float
    active_b: float
    kind: str  # "dense" | "moe"
    reasoning: bool


@dataclass(frozen=True)
class DatasetProfile:
    key: str
    in_median: float
    in_sigma: float
    out_median: float
    out_sigma: float


@dataclass(frozen=True)
class Truth:
    tbt0_s: float
    kappa_dec: float
    c_pre_s: float
    gamma_pre: float
    kappa_pre: float
    a0: float
    dec_min_frac: float
    dec_max_frac: float
    pre_frac: float
    mixed_bonus_frac: float
    noise_w: float
    meas_noise_w: float
    ar_phi: float
    ar_sigma_w: float


@dataclass(frozen=True)
class ServerConfig:
    id: str
    model: str
    gpu: str
    tp: int
    n_gpus_server: int
    truth: Truth


@dataclass(frozen=True)
class Campaign:
    rates: List[float]
    reps: int
    trace_seconds: float
    dt_s: float
    max_batch: int
    reasoning_out_mult: float


@dataclass(frozen=True)
class Catalog:
    gpus: Dict[str, Gpu]
    models: Dict[str, Model]
    datasets: Dict[str, DatasetProfile]
    configs: List[ServerConfig]
    campaign: Campaign
    p_base_w: float
    pue: float

    def config(self, cid: str) -> ServerConfig:
        for c in self.configs:
            if c.id == cid:
                return c
        raise KeyError(f"unknown config '{cid}'")

    def gpu_of(self, cfg: ServerConfig) -> Gpu:
        return self.gpus[cfg.gpu]

    def model_of(self, cfg: ServerConfig) -> Model:
        return self.models[cfg.model]


def load_catalog(path: str = None) -> Catalog:
    if path is None:
        path = os.path.join(repo_root(), "data", "catalog.json")
    with open(path) as f:
        raw = json.load(f)
    gpus = {k: Gpu(key=k, **v) for k, v in raw["gpus"].items()}
    models = {k: Model(key=k, **v) for k, v in raw["models"].items()}
    datasets = {k: DatasetProfile(key=k, **v) for k, v in raw["datasets"].items()}
    configs = [
        ServerConfig(
            id=c["id"],
            model=c["model"],
            gpu=c["gpu"],
            tp=c["tp"],
            n_gpus_server=c["n_gpus_server"],
            truth=Truth(**c["truth"]),
        )
        for c in raw["configs"]
    ]
    camp = Campaign(**raw["campaign"])
    return Catalog(
        gpus=gpus,
        models=models,
        datasets=datasets,
        configs=configs,
        campaign=camp,
        p_base_w=raw["site"]["p_base_w"],
        pue=raw["site"]["pue"],
    )
