"""AOT build orchestrator — `make artifacts` entry point.

Runs the full Python build path once:

1. lowers the L2 BiGRU (with the L1 Pallas GRU kernel on its scan path) to
   HLO **text** (`bigru_fwd.hlo.txt`) and the GMM labeling kernel to
   `gmm_label.hlo.txt` — text, not `.serialize()`: jax ≥ 0.5 emits protos
   with 64-bit ids that xla_extension 0.5.1 rejects (see
   /opt/xla-example/README.md);
2. runs the synthetic measurement campaign (testbed) for every catalog
   configuration: rates × reps Poisson traces, rep-level split;
3. fits GMM + BIC, trains the BiGRU, calibrates the surrogate;
4. exports per-config JSON artifacts, held-out measured test traces, and
   the manifest.

Environment knobs (used by CI/tests, not the default build):
  POWERTRACE_FAST=1          smaller campaign + fewer train steps
  POWERTRACE_CONFIGS=a,b     build only the named configurations
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import testbed, train
from .catalog import load_catalog
from .datasets import out_mult_for, poisson_schedule
from .kernels.gmm import gmm_posterior_pallas
from .model import HIDDEN, K_MAX, bigru_export, flat_param_count

CHUNK_T = 512
CHUNK_HALO = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_hlo(out_dir: str) -> None:
    p_spec = jax.ShapeDtypeStruct((flat_param_count(),), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((CHUNK_T, 2), jnp.float32)
    lowered = jax.jit(bigru_export).lower(p_spec, x_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "bigru_fwd.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)")

    def gmm_label(pi, mu, sigma, y):
        return gmm_posterior_pallas(y, pi, mu, sigma)

    k_spec = jax.ShapeDtypeStruct((K_MAX,), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((CHUNK_T,), jnp.float32)
    lowered = jax.jit(gmm_label).lower(k_spec, k_spec, k_spec, y_spec)
    path = os.path.join(out_dir, "gmm_label.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"[aot] wrote {path}")


def run_campaign(cat, cfg, fast: bool, seed: int):
    """Measured-trace campaign for one configuration (rep-major order so a
    rep-level split covers every arrival rate)."""
    camp = cat.campaign
    rates = camp.rates[1::2] if fast else camp.rates
    reps = 3 if fast else camp.reps
    horizon = 120.0 if fast else camp.trace_seconds
    dataset_keys = sorted(cat.datasets.keys())
    out_mult = out_mult_for(cat, cfg)

    traces, schedules, meta = [], [], []
    for rep in range(reps):
        for ri, rate in enumerate(rates):
            rng = np.random.default_rng(seed * 1_000_003 + rep * 101 + ri)
            profile = cat.datasets[dataset_keys[(rep + ri) % len(dataset_keys)]]
            sched = poisson_schedule(rate, horizon, profile, out_mult, rng)
            tr = testbed.simulate(cat, cfg, sched, horizon, rng)
            traces.append(tr)
            schedules.append(sched)
            meta.append({"rate": rate, "rep": rep})
    n_rates = len(rates)
    n = len(traces)
    test_idx = list(range(n - n_rates, n))              # last rep → test
    val_idx = list(range(n - 2 * n_rates, n - n_rates))  # second-to-last → val
    train_idx = [i for i in range(n) if i not in test_idx and i not in val_idx]
    return traces, schedules, meta, train_idx, val_idx, test_idx


def export_config(out_dir, cat, cfg, fast: bool):
    t0 = time.time()
    seed = abs(hash(cfg.id)) % (2**31)
    traces, schedules, meta, train_idx, val_idx, test_idx = run_campaign(
        cat, cfg, fast, seed
    )
    is_moe = cat.model_of(cfg).kind == "moe"
    n_steps = int(os.environ.get("POWERTRACE_TRAIN_STEPS", "80" if fast else "320"))
    result = train.train_config(
        [t.power_w for t in traces],
        [t.a_measured for t in traces],
        is_moe=is_moe,
        seed=seed,
        n_steps=n_steps,
        train_idx=train_idx,
        val_idx=val_idx,
    )

    # Surrogate calibration from pooled training-trace durations.
    pooled = {"n_in": [], "prefill_s": [], "n_out": [], "decode_s": []}
    for i in train_idx:
        for key in pooled:
            pooled[key].extend(traces[i].durations[key])
    surrogate = train.calibrate_surrogate(pooled)

    # Per-config artifact JSON (format: DESIGN.md §6 / rust artifacts mod).
    pi = result.gmm.pi / result.gmm.pi.sum()
    phi = np.clip(result.phi, 0.0, 0.99)
    train_mean = float(np.mean(np.concatenate([traces[i].power_w for i in train_idx])))
    art = {
        "config_id": cfg.id,
        "k": int(result.k),
        "train_power_mean_w": train_mean,
        "states": {
            "pi": [float(x) for x in pi],
            "mu": [float(x) for x in result.gmm.mu],
            "sigma": [float(max(x, 1e-3)) for x in result.gmm.sigma],
            "phi": [float(x) for x in phi],
            "y_min": result.y_min,
            "y_max": result.y_max,
        },
        "mode": "ar1" if is_moe else "iid",
        "surrogate": surrogate,
        "weights": [float(x) for x in result.flat],
        "train_meta": {
            "val_accuracy": result.val_accuracy,
            "final_loss": result.final_loss,
            "bic_ks": result.bic_ks,
            "bic_vals": result.bic_vals,
            "n_train_traces": len(train_idx),
            "seed": seed,
        },
    }
    cfg_dir = os.path.join(out_dir, "configs")
    os.makedirs(cfg_dir, exist_ok=True)
    with open(os.path.join(cfg_dir, f"{cfg.id}.json"), "w") as f:
        json.dump(art, f)

    # Held-out measured test traces (+ their schedules) for Rust evaluation.
    m_dir = os.path.join(out_dir, "measured", cfg.id)
    os.makedirs(m_dir, exist_ok=True)
    for i in test_idx:
        tr, sched, mt = traces[i], schedules[i], meta[i]
        doc = {
            "rate": mt["rate"],
            "rep": mt["rep"],
            "dt_s": tr.dt_s,
            "power_w": [round(float(x), 3) for x in tr.power_w],
            "a": [round(float(x), 4) for x in tr.a_measured],
            "schedule": sched,
            "durations": {
                "n_in": [int(x) for x in tr.durations["n_in"]],
                "prefill_s": [round(float(x), 5) for x in tr.durations["prefill_s"]],
                "n_out": [int(x) for x in tr.durations["n_out"]],
                "decode_s": [round(float(x), 5) for x in tr.durations["decode_s"]],
            },
        }
        name = f"r{mt['rate']:g}_rep{mt['rep']}.json"
        with open(os.path.join(m_dir, name), "w") as f:
            json.dump(doc, f)

    print(
        f"[aot] {cfg.id}: K={result.k} val_acc={result.val_accuracy:.3f} "
        f"loss={result.final_loss:.4f} ({time.time() - t0:.1f}s)"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-hlo", action="store_true")
    args = ap.parse_args()

    fast = os.environ.get("POWERTRACE_FAST") == "1"
    only = os.environ.get("POWERTRACE_CONFIGS")
    only = set(only.split(",")) if only else None

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    cat = load_catalog()

    if not args.skip_hlo:
        export_hlo(out_dir)

    config_ids = []
    for cfg in cat.configs:
        if only and cfg.id not in only:
            continue
        export_config(out_dir, cat, cfg, fast)
        config_ids.append(cfg.id)

    manifest = {
        "chunk": {"t": CHUNK_T, "halo": CHUNK_HALO},
        "k_max": K_MAX,
        "hidden": HIDDEN,
        "hlo": "bigru_fwd.hlo.txt",
        "configs": config_ids,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(config_ids)} configs → {out_dir}")


if __name__ == "__main__":
    sys.exit(main())
