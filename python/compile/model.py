"""L2 JAX model: the BiGRU temporal state classifier (paper §3.2, Eq. 3).

Operates on a single flat f32 parameter vector so the AOT-compiled HLO can
serve every configuration with weights as a runtime input (layout in
DESIGN.md §6; identical to `rust/src/classifier/native.rs`):

    per direction (fwd, bwd): W_ih [3H,2] · b_ih [3H] · W_hh [3H,H] · b_hh [3H]
    then W_head [K, 2H] · b_head [K]

The log1p feature transform is baked into the model so callers pass raw
`(A_t, ΔA_t)` features on both the Python and Rust sides.

The per-step recurrent update is the L1 Pallas kernel
(`kernels.gru.gru_cell_pallas`); training uses the numerically identical
pure-jnp reference cell for speed (equivalence is pinned by tests).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.gru import gru_cell_pallas
from .kernels.ref import gru_cell_ref

HIDDEN = 64
K_MAX = 12


def scale_features(x):
    """Feature transform baked into the model (keep in sync with
    `rust/src/classifier/native.rs::scale_features`): `log1p` compresses
    the saturating tail of the occupancy→power curve while keeping
    low-occupancy levels (idle vs A=1 vs A=2) well separated.

        A_t  → log1p(A_t) / 2
        ΔA_t → sign(ΔA_t) · log1p(|ΔA_t|) / 2
    """
    a = x[..., 0:1]
    da = x[..., 1:2]
    import jax.numpy as _jnp

    fa = _jnp.log1p(_jnp.maximum(a, 0.0)) * 0.5
    fda = _jnp.sign(da) * _jnp.log1p(_jnp.abs(da)) * 0.5
    return _jnp.concatenate([fa, fda], axis=-1)


def flat_param_count(h: int = HIDDEN, k: int = K_MAX) -> int:
    return 2 * (3 * h * 2 + 3 * h + 3 * h * h + 3 * h) + k * 2 * h + k


def unpack_params(flat, h: int = HIDDEN, k: int = K_MAX):
    """Flat vector → pytree of weight views (transposes precomputed)."""
    block = 3 * h * 2 + 3 * h + 3 * h * h + 3 * h
    dirs = []
    o = 0
    for _ in range(2):
        w_ih = flat[o:o + 3 * h * 2].reshape(3 * h, 2)
        o += 3 * h * 2
        b_ih = flat[o:o + 3 * h]
        o += 3 * h
        w_hh = flat[o:o + 3 * h * h].reshape(3 * h, h)
        o += 3 * h * h
        b_hh = flat[o:o + 3 * h]
        o += 3 * h
        dirs.append({"w_ih": w_ih, "b_ih": b_ih, "w_hh_t": w_hh.T, "b_hh": b_hh})
    w_head = flat[o:o + k * 2 * h].reshape(k, 2 * h)
    o += k * 2 * h
    b_head = flat[o:o + k]
    o += k
    assert o == block * 2 + k * 2 * h + k
    return {"dirs": dirs, "w_head": w_head, "b_head": b_head}


def pack_params(params, h: int = HIDDEN, k: int = K_MAX):
    """Inverse of `unpack_params` (training state → artifact vector)."""
    parts = []
    for d in params["dirs"]:
        parts.append(d["w_ih"].reshape(-1))
        parts.append(d["b_ih"])
        parts.append(d["w_hh_t"].T.reshape(-1))
        parts.append(d["b_hh"])
    parts.append(params["w_head"].reshape(-1))
    parts.append(params["b_head"])
    flat = jnp.concatenate(parts)
    assert flat.shape[0] == flat_param_count(h, k)
    return flat


def _run_direction(d, xs, cell):
    """One GRU direction over [B, T, 2] pre-scaled features → [B, T, H].

    The input projection is hoisted out of the scan as a single batched
    matmul (L2 perf note, DESIGN.md §9) — the scan body only carries the
    recurrent matmul, which is the Pallas kernel.
    """
    gi = jnp.einsum("btj,gj->btg", xs, d["w_ih"]) + d["b_ih"]  # [B,T,3H]
    gi_t = jnp.swapaxes(gi, 0, 1)  # [T,B,3H]
    h0 = jnp.zeros((xs.shape[0], d["w_hh_t"].shape[0]), xs.dtype)

    def step(h_prev, gi_step):
        h_next = cell(h_prev, gi_step, d["w_hh_t"], d["b_hh"])
        return h_next, h_next

    _, hs = jax.lax.scan(step, h0, gi_t)
    return jnp.swapaxes(hs, 0, 1)  # [B,T,H]


def bigru_probs(flat, x, use_pallas: bool = False, h: int = HIDDEN, k: int = K_MAX):
    """Classifier forward: raw features [B, T, 2] → posteriors [B, T, K].

    `use_pallas=True` routes the recurrent update through the L1 kernel
    (export path); `False` uses the pure-jnp reference (training path).
    """
    cell = gru_cell_pallas if use_pallas else gru_cell_ref
    p = unpack_params(flat, h, k)
    xs = scale_features(x)
    h_fwd = _run_direction(p["dirs"][0], xs, cell)
    h_bwd = jnp.flip(_run_direction(p["dirs"][1], jnp.flip(xs, axis=1), cell), axis=1)
    hidden = jnp.concatenate([h_fwd, h_bwd], axis=-1)  # [B,T,2H]
    logits = jnp.einsum("bth,kh->btk", hidden, p["w_head"]) + p["b_head"]
    return jax.nn.softmax(logits, axis=-1)


def bigru_logits(flat, x, use_pallas: bool = False, h: int = HIDDEN, k: int = K_MAX):
    """Same forward but returning logits (training loss needs them)."""
    cell = gru_cell_pallas if use_pallas else gru_cell_ref
    p = unpack_params(flat, h, k)
    xs = scale_features(x)
    h_fwd = _run_direction(p["dirs"][0], xs, cell)
    h_bwd = jnp.flip(_run_direction(p["dirs"][1], jnp.flip(xs, axis=1), cell), axis=1)
    hidden = jnp.concatenate([h_fwd, h_bwd], axis=-1)
    return jnp.einsum("bth,kh->btk", hidden, p["w_head"]) + p["b_head"]


@functools.partial(jax.jit, static_argnames=())
def bigru_export(flat, x):
    """The AOT entry point: (flat [P], x [T,2]) → probs [T, K_MAX].

    Single sequence (B=1 squeezed); the Pallas GRU kernel is on the scan
    path so it lowers into the exported HLO.
    """
    return bigru_probs(flat, x[None], use_pallas=True)[0]


def init_params(rng, h: int = HIDDEN, k: int = K_MAX):
    """Glorot-ish init in packed form (numpy RNG for determinism)."""
    import numpy as np

    def glorot(shape, fan_in, fan_out):
        limit = np.sqrt(6.0 / (fan_in + fan_out))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)

    parts = []
    for _ in range(2):
        parts.append(glorot((3 * h, 2), 2, h).reshape(-1))
        parts.append(np.zeros(3 * h, np.float32))
        parts.append(glorot((3 * h, h), h, h).reshape(-1))
        parts.append(np.zeros(3 * h, np.float32))
    parts.append(glorot((k, 2 * h), 2 * h, k).reshape(-1))
    parts.append(np.zeros(k, np.float32))
    flat = np.concatenate(parts)
    assert flat.shape[0] == flat_param_count(h, k)
    return flat
