"""EM fitting of 1-D Gaussian mixtures with BIC model selection
(paper §3.2 / Fig. 4). Vectorized numpy; mirrors `rust/src/states/em.rs`
(which is cross-checked by integration tests on planted mixtures)."""

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class Gmm:
    pi: np.ndarray     # [K]
    mu: np.ndarray     # [K] (ascending)
    sigma: np.ndarray  # [K]

    @property
    def k(self) -> int:
        return len(self.pi)

    def log_likelihood(self, y: np.ndarray) -> float:
        return float(np.sum(_logsumexp(self._log_joint(y), axis=1)))

    def _log_joint(self, y: np.ndarray) -> np.ndarray:
        y = y[:, None]
        return (
            np.log(np.maximum(self.pi, 1e-300))[None, :]
            - 0.5 * ((y - self.mu[None, :]) / self.sigma[None, :]) ** 2
            - np.log(self.sigma)[None, :]
            - 0.5 * np.log(2 * np.pi)
        )

    def labels(self, y: np.ndarray) -> np.ndarray:
        """Hard state labels by posterior maximization (paper Eq. 2)."""
        return np.argmax(self._log_joint(y), axis=1)

    def bic(self, y: np.ndarray) -> float:
        n_params = 3 * self.k - 1
        return n_params * np.log(len(y)) - 2.0 * self.log_likelihood(y)


def _logsumexp(x, axis):
    m = np.max(x, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(x - m), axis=axis, keepdims=True))).squeeze(axis)


def fit_gmm(y: np.ndarray, k: int, rng: np.random.Generator,
            n_init: int = 3, max_iters: int = 200, tol: float = 1e-6) -> Gmm:
    """Fit a K-component 1-D GMM by EM with k-means++-style seeding."""
    assert len(y) >= 10 * k, f"need >= {10*k} samples for k={k}"
    var = float(np.var(y))
    var_floor = max(var * 1e-4, 1e-9)

    best = None
    best_ll = -np.inf
    for _ in range(n_init):
        mu = _seed_means(y, k, rng)
        pi = np.full(k, 1.0 / k)
        sigma = np.full(k, max(np.sqrt(var / k), np.sqrt(var_floor)))
        prev_ll = -np.inf
        for _ in range(max_iters):
            g = Gmm(pi=pi, mu=mu, sigma=sigma)
            lj = g._log_joint(y)
            m = np.max(lj, axis=1, keepdims=True)
            r = np.exp(lj - m)
            r /= np.sum(r, axis=1, keepdims=True)
            nk = np.maximum(r.sum(axis=0), 1e-12)
            pi = nk / len(y)
            mu = (r * y[:, None]).sum(axis=0) / nk
            v = (r * (y[:, None] - mu[None, :]) ** 2).sum(axis=0) / nk
            sigma = np.sqrt(np.maximum(v, var_floor))
            ll = float(np.sum(m.squeeze(1) + np.log(np.sum(np.exp(lj - m), axis=1)))) / len(y)
            if abs(ll - prev_ll) < tol:
                prev_ll = ll
                break
            prev_ll = ll
        if prev_ll > best_ll:
            best_ll = prev_ll
            best = Gmm(pi=pi, mu=mu, sigma=sigma)
    order = np.argsort(best.mu)
    return Gmm(pi=best.pi[order], mu=best.mu[order], sigma=best.sigma[order])


def _seed_means(y: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    means = [float(y[rng.integers(len(y))])]
    sub = y[:: max(len(y) // 2048, 1)]
    while len(means) < k:
        d2 = np.min((sub[:, None] - np.asarray(means)[None, :]) ** 2, axis=1)
        total = d2.sum()
        if total <= 0:
            means.append(float(sub[rng.integers(len(sub))]))
            continue
        means.append(float(sub[rng.choice(len(sub), p=d2 / total)]))
    return np.asarray(sorted(means))


def select_k(y: np.ndarray, k_range, rng: np.random.Generator,
             plateau_frac: float = 0.02) -> Tuple[Gmm, List[int], List[float]]:
    """Fit each K, return (best fit, ks, bics) with the paper's plateau rule
    (smallest K within `plateau_frac` of the BIC span above the minimum)."""
    ks, bics, fits = [], [], []
    for k in k_range:
        g = fit_gmm(y, k, rng)
        ks.append(k)
        bics.append(g.bic(y))
        fits.append(g)
    lo, hi = min(bics), max(bics)
    thresh = lo + plateau_frac * max(hi - lo, 1e-12)
    idx = next(i for i, b in enumerate(bics) if b <= thresh)
    return fits[idx], ks, bics


def estimate_ar1_phi(y: np.ndarray, labels: np.ndarray, gmm: Gmm) -> np.ndarray:
    """Per-state AR(1) coefficient from consecutive same-state samples
    (paper Eq. 9: φ_k estimated from segments in the training data)."""
    phis = np.zeros(gmm.k)
    for k in range(gmm.k):
        mask = (labels[:-1] == k) & (labels[1:] == k)
        if mask.sum() < 20:
            continue
        a = y[:-1][mask] - gmm.mu[k]
        b = y[1:][mask] - gmm.mu[k]
        denom = float(np.sqrt(np.sum(a * a) * np.sum(b * b)))
        if denom > 1e-12:
            phis[k] = float(np.clip(np.sum(a * b) / denom, 0.0, 0.99))
    return phis
