"""L1 Pallas kernel: GMM posterior responsibilities (paper Eq. 2).

Grid over blocks of samples; per block the kernel evaluates K Gaussian
log-densities, applies the mixture priors, and normalizes with a stable
softmax — one VMEM round trip per sample block. Used by the build-time
labeling path and exported as `gmm_label.hlo.txt` for runtime sanity
checks from Rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _gmm_kernel(y_ref, pi_ref, mu_ref, sigma_ref, out_ref):
    y = y_ref[...]          # [Nt, 1]
    pi = pi_ref[...]        # [1, K]
    mu = mu_ref[...]        # [1, K]
    sigma = sigma_ref[...]  # [1, K]
    log_prob = (
        jnp.log(jnp.maximum(pi, 1e-30))
        - 0.5 * ((y - mu) / sigma) ** 2
        - jnp.log(sigma)
    )  # [Nt, K]
    m = jnp.max(log_prob, axis=1, keepdims=True)
    p = jnp.exp(log_prob - m)
    out_ref[...] = p / jnp.sum(p, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=())
def gmm_posterior_pallas(y, pi, mu, sigma):
    """Pallas version of `ref.gmm_posterior_ref` (same signature)."""
    n = y.shape[0]
    k = pi.shape[0]
    block_n = min(BLOCK_N, n)
    grid = (pl.cdiv(n, block_n),)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(y.reshape(n, 1), pi.reshape(1, k), mu.reshape(1, k), sigma.reshape(1, k))
