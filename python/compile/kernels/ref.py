"""Pure-jnp oracles for the L1 Pallas kernels (the correctness baseline the
pytest + hypothesis suites compare against) and the reference cell used by
the training loop (interpret-mode Pallas is too slow for training; the two
paths are asserted numerically identical by tests)."""

import jax.numpy as jnp


def gru_cell_ref(h, gi, w_hh_t, b_hh):
    """One GRU hidden-state update (torch gate order r, z, n).

    h      : [B, H]   previous hidden state
    gi     : [B, 3H]  input projection W_ih·x + b_ih (precomputed per step)
    w_hh_t : [H, 3H]  transposed recurrent weights (MXU-friendly layout)
    b_hh   : [3H]
    returns h' : [B, H]
    """
    hd = h.shape[-1]
    gh = jnp.dot(h, w_hh_t) + b_hh  # [B, 3H]
    r = jnp.reciprocal(1.0 + jnp.exp(-(gi[:, :hd] + gh[:, :hd])))
    z = jnp.reciprocal(1.0 + jnp.exp(-(gi[:, hd:2 * hd] + gh[:, hd:2 * hd])))
    n = jnp.tanh(gi[:, 2 * hd:] + r * gh[:, 2 * hd:])
    return (1.0 - z) * n + z * h


def gmm_posterior_ref(y, pi, mu, sigma):
    """GMM posterior responsibilities (paper Eq. 2 before the argmax).

    y : [N]; pi, mu, sigma : [K]  →  [N, K] rows summing to 1.
    """
    y = y[:, None]
    log_prob = (
        jnp.log(jnp.maximum(pi, 1e-30))[None, :]
        - 0.5 * ((y - mu[None, :]) / sigma[None, :]) ** 2
        - jnp.log(sigma)[None, :]
    )
    m = jnp.max(log_prob, axis=1, keepdims=True)
    p = jnp.exp(log_prob - m)
    return p / jnp.sum(p, axis=1, keepdims=True)
