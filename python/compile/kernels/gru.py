"""L1 Pallas kernel: fused GRU hidden-state update.

The kernel fuses the recurrent matmul `h · W_hhᵀ` (the MXU work) with the
gate nonlinearities (VPU work) so the hidden state makes one round trip
through VMEM per step instead of three.

TPU mapping (DESIGN.md §Hardware-Adaptation / §9): the batch dimension is
tiled through VMEM in blocks of `BLOCK_B` rows; per block the resident set
is W_hhᵀ (64×192×4 B = 48 KiB) + h tile (≤64×64×4 B = 16 KiB) + gi tile
(≤64×192×4 B = 48 KiB) ≈ 112 KiB ≪ 16 MiB VMEM, and the matmul is a
[B,64]×[64,192] MXU op. `interpret=True` is mandatory here: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile (rows of h processed per grid step).
BLOCK_B = 64


def _gru_kernel(h_ref, gi_ref, whht_ref, bhh_ref, out_ref):
    h = h_ref[...]          # [Bt, H]
    gi = gi_ref[...]        # [Bt, 3H]
    w = whht_ref[...]       # [H, 3H]
    b = bhh_ref[...]        # [1, 3H]
    hd = h.shape[-1]
    gh = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
    r = jnp.reciprocal(1.0 + jnp.exp(-(gi[:, :hd] + gh[:, :hd])))
    z = jnp.reciprocal(1.0 + jnp.exp(-(gi[:, hd:2 * hd] + gh[:, hd:2 * hd])))
    n = jnp.tanh(gi[:, 2 * hd:] + r * gh[:, 2 * hd:])
    out_ref[...] = (1.0 - z) * n + z * h


@functools.partial(jax.jit, static_argnames=())
def gru_cell_pallas(h, gi, w_hh_t, b_hh):
    """Pallas version of `ref.gru_cell_ref` (same signature/semantics)."""
    bsz, hd = h.shape
    g3 = 3 * hd
    block_b = min(BLOCK_B, bsz)
    grid = (pl.cdiv(bsz, block_b),)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, hd), lambda i: (i, 0)),
            pl.BlockSpec((block_b, g3), lambda i: (i, 0)),
            pl.BlockSpec((hd, g3), lambda i: (0, 0)),
            pl.BlockSpec((1, g3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hd), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(h, gi, w_hh_t, b_hh.reshape(1, g3))
