"""Per-configuration training (paper §4.1 "Training"):

1. pool the training traces' power samples, fit the GMM with BIC-selected K;
2. hard-label every timestep (Eq. 2) and estimate per-state AR(1) φ (Eq. 9);
3. train the BiGRU classifier on (A_t, ΔA_t) → label with windowed BPTT and
   hand-rolled Adam (optax is unavailable offline);
4. calibrate the throughput surrogate (Eq. 4–5) from realized durations.

The trace-level split is 70/15/15 train/val/test after pooling across
arrival rates, as in the paper; held-out test traces are exported for the
Rust evaluation harness.
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gmmfit
from .model import K_MAX, bigru_logits, flat_param_count, init_params


@dataclass
class TrainResult:
    flat: np.ndarray          # trained weights
    k: int
    gmm: gmmfit.Gmm
    phi: np.ndarray           # per-state AR(1)
    y_min: float
    y_max: float
    bic_ks: List[int]
    bic_vals: List[float]
    val_accuracy: float
    final_loss: float


def features_from_a(a_measured: np.ndarray) -> np.ndarray:
    """(A_t, ΔA_t) features [T,2] from the measured mean-occupancy series."""
    a = np.round(a_measured).astype(np.float32)
    da = np.diff(a, prepend=0.0).astype(np.float32)
    return np.stack([a, da], axis=1)


def _loss(flat, xb, yb, pb, mu_pad, p_scale, w_energy):
    """Cross-entropy on GMM labels plus an energy-calibration term.

    The auxiliary term matches the posterior-expected power `probs·mu`
    to the measured power, normalizing the paper's headline ΔEnergy
    metric directly (the paper selected the BiGRU for "downstream energy
    fidelity"; with one CPU core we cannot buy calibration with longer
    training, so we optimize for it explicitly)."""
    logits = bigru_logits(flat, xb)  # [B,W,K_MAX] (ref cell: training path)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, yb[..., None], axis=-1)[..., 0]
    ce = -jnp.mean(picked)
    probs = jnp.exp(logp)
    pred_power = probs @ mu_pad  # [B,W]
    aux = jnp.mean(((pred_power - pb) / p_scale) ** 2)
    return ce + w_energy * aux


_loss_and_grad = jax.jit(jax.value_and_grad(_loss))


def _adam_update(flat, g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    return flat - lr * mhat / (np.sqrt(vhat) + eps), m, v


def split_traces(n: int) -> Tuple[List[int], List[int], List[int]]:
    """Deterministic 70/15/15 split by trace index (train/val/test)."""
    idx = list(range(n))
    n_test = max(1, round(0.15 * n))
    n_val = max(1, round(0.15 * n))
    test = idx[::-1][:n_test]            # last traces (highest rep) → test
    val = idx[::-1][n_test:n_test + n_val]
    train = [i for i in idx if i not in test and i not in val]
    return train, val, test


def _sample_batch(feats, labels, powers, window, batch, rng):
    xb = np.zeros((batch, window, 2), np.float32)
    yb = np.zeros((batch, window), np.int32)
    pb = np.zeros((batch, window), np.float32)
    for b in range(batch):
        ti = rng.integers(len(feats))
        f, l, p = feats[ti], labels[ti], powers[ti]
        if len(l) <= window:
            xb[b, : len(l)] = f
            yb[b, : len(l)] = l
            pb[b, : len(l)] = p
        else:
            s = rng.integers(len(l) - window)
            xb[b] = f[s : s + window]
            yb[b] = l[s : s + window]
            pb[b] = p[s : s + window]
    return jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(pb)


def train_config(power_traces: List[np.ndarray], a_traces: List[np.ndarray],
                 is_moe: bool, seed: int,
                 n_steps: int = 300, window: int = 128, batch: int = 8,
                 lr: float = 4e-3, w_energy: float = 1.0, k_range=range(4, 13),
                 train_idx: List[int] = None, val_idx: List[int] = None) -> TrainResult:
    """Full §3.2 training for one configuration. Returns everything the
    per-config artifact needs. `train_idx`/`val_idx` override the default
    trace-level split (the campaign uses a rep-level split so every arrival
    rate appears in each partition)."""
    rng = np.random.default_rng(seed)
    if train_idx is None or val_idx is None:
        train_idx, val_idx, _ = split_traces(len(power_traces))

    # --- GMM on pooled training power ---
    pooled = np.concatenate([power_traces[i] for i in train_idx]).astype(np.float64)
    gmm, bic_ks, bic_vals = gmmfit.select_k(pooled, k_range, rng)
    k = gmm.k

    # --- labels + features ---
    feats = [features_from_a(a) for a in a_traces]
    labels = [gmm.labels(p.astype(np.float64)).astype(np.int32) for p in power_traces]

    # --- AR(1) φ per state (MoE only; dense uses i.i.d. sampling) ---
    if is_moe:
        phi = gmmfit.estimate_ar1_phi(pooled, gmm.labels(pooled), gmm)
    else:
        phi = np.zeros(k)

    # --- BiGRU training (ref cell path) ---
    flat = init_params(rng).astype(np.float32)
    m = np.zeros_like(flat)
    v = np.zeros_like(flat)
    train_feats = [feats[i] for i in train_idx]
    train_labels = [labels[i] for i in train_idx]
    train_powers = [power_traces[i].astype(np.float32) for i in train_idx]
    # Posterior-expected power uses the state means; pad unused logit slots
    # with the top state's mean (their probability is driven to ~0 by CE).
    mu_pad = np.full(K_MAX, gmm.mu[-1], np.float32)
    mu_pad[:k] = gmm.mu
    mu_pad = jnp.asarray(mu_pad)
    p_scale = jnp.float32(max(float(pooled.mean()), 1.0))
    final_loss = float("nan")
    for step in range(1, n_steps + 1):
        # Cosine decay: calibration benefits from a small final lr.
        lr_t = lr * (0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * (step - 1) / n_steps)))
        xb, yb, pb = _sample_batch(train_feats, train_labels, train_powers,
                                   window, batch, rng)
        loss, g = _loss_and_grad(jnp.asarray(flat), xb, yb, pb, mu_pad,
                                 p_scale, jnp.float32(w_energy))
        flat, m, v = _adam_update(flat, np.asarray(g), m, v, step, lr_t)
        final_loss = float(loss)

    # --- validation accuracy (argmax vs GMM label) ---
    correct = 0
    total = 0
    for i in val_idx:
        logits = bigru_logits(jnp.asarray(flat), jnp.asarray(feats[i][None]))
        pred = np.argmax(np.asarray(logits[0]), axis=1)
        correct += int((pred == labels[i]).sum())
        total += len(labels[i])
    val_acc = correct / max(total, 1)

    return TrainResult(
        flat=np.asarray(flat, np.float32),
        k=k,
        gmm=gmm,
        phi=phi,
        y_min=float(pooled.min()),
        y_max=float(pooled.max()),
        bic_ks=bic_ks,
        bic_vals=bic_vals,
        val_accuracy=val_acc,
        final_loss=final_loss,
    )


def calibrate_surrogate(durations: Dict[str, list]) -> Dict[str, float]:
    """OLS fit of the throughput surrogate (paper Eq. 4–5); mirror of
    `rust/src/surrogate/calibrate.rs`."""
    n_in = np.asarray(durations["n_in"], np.float64)
    pre = np.asarray(durations["prefill_s"], np.float64)
    n_out = np.asarray(durations["n_out"], np.float64)
    dec = np.asarray(durations["decode_s"], np.float64)
    assert len(n_in) >= 8, "need >= 8 duration samples to calibrate"

    x = np.log(n_in + 1.0)
    y = np.log(pre)
    mx, my = x.mean(), y.mean()
    sxx = float(np.sum((x - mx) ** 2))
    if sxx < 1e-9:
        alpha0, alpha1 = my, 0.0
    else:
        alpha1 = float(np.sum((x - mx) * (y - my)) / sxx)
        alpha0 = my - alpha1 * mx
    resid = y - (alpha0 + alpha1 * x)
    log_tbt = np.log(dec / np.maximum(n_out, 1))
    return {
        "alpha0": float(alpha0),
        "alpha1": float(alpha1),
        "sigma_ttft": float(np.sqrt(np.mean(resid ** 2))),
        "mu_log_tbt": float(np.mean(log_tbt)),
        "sigma_log_tbt": float(np.std(log_tbt)),
    }
