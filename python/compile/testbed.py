"""Python testbed — the synthetic measurement campaign.

Exact mirror of `rust/src/testbed/engine.rs` (same math, same catalog):
a time-stepped continuous-batching engine plus the physically-motivated
GPU power law. Used at build time to generate the "measured" traces the
pipeline learns from; cross-consistency with the Rust mirror is enforced
by an integration test comparing summary statistics on a fixed schedule.
"""

import math
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from .catalog import Catalog, ServerConfig


def utilization(truth, a: int, prefill_present: bool) -> float:
    """Keep in sync with rust/src/testbed/mod.rs::utilization."""
    if a == 0:
        return 0.0
    if prefill_present:
        mix = min((a - 1.0) / 16.0, 1.0)
        return min(truth.pre_frac + truth.mixed_bonus_frac * mix, 1.0)
    sat = 1.0 - math.exp(-((a - 1.0) / truth.a0))
    return truth.dec_min_frac + (truth.dec_max_frac - truth.dec_min_frac) * sat


def server_gpu_power_w(cfg: ServerConfig, gpu, u: float) -> float:
    p_gpu = gpu.idle_w + (gpu.tdp_w - gpu.idle_w) * u
    return cfg.tp * p_gpu + (cfg.n_gpus_server - cfg.tp) * gpu.idle_w


@dataclass
class TestbedTrace:
    dt_s: float
    power_w: np.ndarray       # [n_windows] f32
    a_measured: np.ndarray    # [n_windows] f32 (mean occupancy per window)
    prefill_frac: np.ndarray  # [n_windows] f32
    durations: Dict[str, list] = field(default_factory=dict)
    starts: List[float] = field(default_factory=list)


def simulate(cat: Catalog, cfg: ServerConfig, schedule, horizon_s: float,
             rng: np.random.Generator, dt_sim: float = 0.05) -> TestbedTrace:
    """Run the testbed for one server over a schedule of dicts
    {"t", "n_in", "n_out"} sorted by arrival time."""
    truth = cfg.truth
    gpu = cat.gpu_of(cfg)
    dt_sample = cat.campaign.dt_s
    max_batch = cat.campaign.max_batch
    b_cap = float(max_batch)
    n_windows = int(round(horizon_s / dt_sample))
    steps_per_window = max(int(round(dt_sample / dt_sim)), 1)

    pending: List[int] = []
    next_arrival = 0
    # running request state (parallel lists)
    r_idx: List[int] = []
    r_n_in: List[int] = []
    r_n_out: List[int] = []
    r_prefill_left: List[float] = []
    r_tokens_left: List[float] = []
    r_started: List[float] = []
    r_pre_done: List[float] = []  # NaN until prefill completes

    starts = [float("nan")] * len(schedule)
    durations = {"n_in": [], "prefill_s": [], "n_out": [], "decode_s": []}
    power_w = np.zeros(n_windows, dtype=np.float32)
    a_measured = np.zeros(n_windows, dtype=np.float32)
    prefill_frac = np.zeros(n_windows, dtype=np.float32)

    ar_state = 0.0
    ar_innov = truth.ar_sigma_w * math.sqrt(max(1.0 - truth.ar_phi ** 2, 0.0))

    t = 0.0
    for w in range(n_windows):
        u_sum = 0.0
        a_sum = 0.0
        pre_steps = 0
        for _ in range(steps_per_window):
            # 1. arrivals
            while next_arrival < len(schedule) and schedule[next_arrival]["t"] <= t:
                pending.append(next_arrival)
                next_arrival += 1
            # 2. admission
            while len(r_idx) < max_batch and pending:
                i = pending.pop(0)
                req = schedule[i]
                starts[i] = t
                r_idx.append(i)
                r_n_in.append(req["n_in"])
                r_n_out.append(req["n_out"])
                r_prefill_left.append(1.0)
                r_tokens_left.append(float(req["n_out"]))
                r_started.append(t)
                r_pre_done.append(float("nan"))
            # 3. progress
            b = len(r_idx)
            if b > 0:
                interference = (b - 1.0) / b_cap
                pre_slow = 1.0 + truth.kappa_pre * interference
                dec_rate = 1.0 / (truth.tbt0_s * (1.0 + truth.kappa_dec * interference))
                prefill_present = False
                for j in range(b):
                    if r_prefill_left[j] > 0.0:
                        prefill_present = True
                        ttft_base = truth.c_pre_s * (r_n_in[j] / 512.0) ** truth.gamma_pre
                        r_prefill_left[j] -= dt_sim / (max(ttft_base, 1e-6) * pre_slow)
                        if r_prefill_left[j] <= 0.0:
                            r_pre_done[j] = t + dt_sim
                    else:
                        r_tokens_left[j] -= dec_rate * dt_sim
                u_sum += utilization(truth, b, prefill_present)
                a_sum += b
                if prefill_present:
                    pre_steps += 1
                # 4. completions
                end_t = t + dt_sim
                keep = []
                for j in range(b):
                    if r_prefill_left[j] <= 0.0 and r_tokens_left[j] <= 0.0:
                        pre_end = r_pre_done[j]
                        if math.isnan(pre_end):
                            pre_end = end_t
                        durations["n_in"].append(r_n_in[j])
                        durations["prefill_s"].append(max(pre_end - r_started[j], dt_sim))
                        durations["n_out"].append(r_n_out[j])
                        durations["decode_s"].append(max(end_t - pre_end, dt_sim))
                    else:
                        keep.append(j)
                if len(keep) != b:
                    r_idx = [r_idx[j] for j in keep]
                    r_n_in = [r_n_in[j] for j in keep]
                    r_n_out = [r_n_out[j] for j in keep]
                    r_prefill_left = [r_prefill_left[j] for j in keep]
                    r_tokens_left = [r_tokens_left[j] for j in keep]
                    r_started = [r_started[j] for j in keep]
                    r_pre_done = [r_pre_done[j] for j in keep]
            t += dt_sim
        # 5. sample window
        u_avg = u_sum / steps_per_window
        p = server_gpu_power_w(cfg, gpu, u_avg)
        p += math.sqrt(cfg.tp) * truth.noise_w * rng.standard_normal()
        if truth.ar_sigma_w > 0.0:
            ar_state = truth.ar_phi * ar_state + ar_innov * rng.standard_normal()
            if a_sum > 0.0:
                p += ar_state * cfg.tp
        p += truth.meas_noise_w * rng.standard_normal()
        floor = cfg.n_gpus_server * gpu.idle_w * 0.95
        ceil = cfg.n_gpus_server * gpu.tdp_w
        power_w[w] = min(max(p, floor), ceil)
        a_measured[w] = a_sum / steps_per_window
        prefill_frac[w] = pre_steps / steps_per_window

    return TestbedTrace(
        dt_s=dt_sample,
        power_w=power_w,
        a_measured=a_measured,
        prefill_frac=prefill_frac,
        durations=durations,
        starts=starts,
    )
