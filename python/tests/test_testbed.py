"""Testbed invariants (mirror of rust/src/testbed tests: same catalog, same
math — the Rust side has an integration test comparing the two engines'
statistics on a fixed schedule)."""

import numpy as np
import pytest

from compile.catalog import load_catalog
from compile.datasets import poisson_schedule
from compile.testbed import simulate, utilization


@pytest.fixture(scope="module")
def cat():
    return load_catalog()


def test_idle_server_draws_idle_power(cat):
    cfg = cat.config("llama8b_a100_tp2")
    gpu = cat.gpu_of(cfg)
    tr = simulate(cat, cfg, [], 60.0, np.random.default_rng(1))
    assert len(tr.power_w) == 240
    assert abs(tr.power_w.mean() - 8 * gpu.idle_w) < 10
    assert np.all(tr.a_measured == 0)


def test_power_within_physical_bounds(cat):
    for cid in ["llama70b_a100_tp8", "gptoss120b_a100_tp4"]:
        cfg = cat.config(cid)
        gpu = cat.gpu_of(cfg)
        rng = np.random.default_rng(2)
        sched = poisson_schedule(2.0, 60.0, cat.datasets["sharegpt"], 1.0, rng)
        tr = simulate(cat, cfg, sched, 60.0, rng)
        assert np.all(tr.power_w >= 8 * gpu.idle_w * 0.95 - 1e-3)
        assert np.all(tr.power_w <= 8 * gpu.tdp_w + 1e-3)
        assert np.all(tr.a_measured <= cat.campaign.max_batch)


def test_requests_complete_and_durations_logged(cat):
    cfg = cat.config("llama8b_a100_tp2")
    rng = np.random.default_rng(3)
    sched = poisson_schedule(0.5, 120.0, cat.datasets["sharegpt"], 1.0, rng)
    tr = simulate(cat, cfg, sched, 400.0, rng)
    assert len(tr.durations["n_in"]) == len(sched)
    assert all(p > 0 for p in tr.durations["prefill_s"])
    assert all(d > 0 for d in tr.durations["decode_s"])
    assert all(np.isfinite(tr.starts))


def test_ttft_superlinear_in_prompt_length(cat):
    cfg = cat.config("llama8b_h100_tp1")
    rng = np.random.default_rng(4)
    short = simulate(cat, cfg, [{"t": 0.0, "n_in": 512, "n_out": 10}], 60.0, rng)
    long = simulate(cat, cfg, [{"t": 0.0, "n_in": 4096, "n_out": 10}], 60.0, rng)
    ratio = long.durations["prefill_s"][0] / short.durations["prefill_s"][0]
    assert ratio > 8.0  # gamma 1.15 > linear (8x)


def test_utilization_shape(cat):
    t = cat.config("llama70b_a100_tp8").truth
    assert utilization(t, 0, False) == 0.0
    us = [utilization(t, a, False) for a in range(1, 64)]
    assert all(b >= a - 1e-12 for a, b in zip(us, us[1:]))
    assert utilization(t, 8, True) > utilization(t, 8, False)
    assert utilization(t, 64, True) <= 1.0


def test_moe_has_stronger_short_lag_autocorrelation(cat):
    def lag1(cid, seed):
        cfg = cat.config(cid)
        rng = np.random.default_rng(seed)
        sched = poisson_schedule(1.0, 240.0, cat.datasets["sharegpt"], 1.0, rng)
        tr = simulate(cat, cfg, sched, 240.0, rng)
        y = tr.power_w - tr.power_w.mean()
        return float((y[:-1] * y[1:]).sum() / (y * y).sum())

    assert lag1("gptoss120b_a100_tp4", 5) > lag1("llama8b_a100_tp2", 5) - 0.05


def test_substep_invariance(cat):
    # Halving dt_sim should barely change mean power (noise is per-window).
    cfg = cat.config("llama8b_a100_tp2")
    sched = [{"t": 1.0, "n_in": 512, "n_out": 200}, {"t": 5.0, "n_in": 256, "n_out": 100}]
    a = simulate(cat, cfg, sched, 60.0, np.random.default_rng(6), dt_sim=0.05)
    b = simulate(cat, cfg, sched, 60.0, np.random.default_rng(6), dt_sim=0.025)
    assert abs(a.power_w.mean() - b.power_w.mean()) / a.power_w.mean() < 0.02
