"""Training-path tests: loss decreases, classifier beats chance on a
separable synthetic problem, surrogate calibration recovers planted
parameters, and split coverage."""

import numpy as np
import pytest

from compile.train import (
    calibrate_surrogate,
    features_from_a,
    split_traces,
    train_config,
)


def synthetic_traces(n_traces=8, t_len=400, seed=0):
    """Power is a clean function of occupancy: trivially learnable."""
    rng = np.random.default_rng(seed)
    powers, a_series = [], []
    for _ in range(n_traces):
        a = np.maximum(rng.integers(-1, 2, size=t_len).cumsum(), 0).astype(np.float32)
        a = np.minimum(a, 8)
        p = 100.0 + 50.0 * a + rng.normal(0, 3, t_len)
        powers.append(p.astype(np.float32))
        a_series.append(a)
    return powers, a_series


def test_train_learns_separable_problem():
    powers, a_series = synthetic_traces()
    res = train_config(
        powers, a_series, is_moe=False, seed=1, n_steps=120, window=64,
        batch=4, k_range=range(2, 7),
    )
    # On a clean staircase the classifier should be far above chance.
    assert res.val_accuracy > 2.0 / res.k, f"acc={res.val_accuracy}, k={res.k}"
    assert np.isfinite(res.final_loss)
    assert res.k >= 2
    assert res.y_min < res.y_max
    assert len(res.flat) == 27_660


def test_features_from_a():
    f = features_from_a(np.array([0.0, 1.2, 2.7, 2.7]))
    assert f.shape == (4, 2)
    assert list(f[:, 0]) == [0.0, 1.0, 3.0, 3.0]
    assert list(f[:, 1]) == [0.0, 1.0, 2.0, 0.0]


def test_split_traces_disjoint_and_complete():
    tr, va, te = split_traces(24)
    all_idx = sorted(tr + va + te)
    assert all_idx == list(range(24))
    assert not (set(tr) & set(va)) and not (set(tr) & set(te)) and not (set(va) & set(te))


def test_calibrate_surrogate_recovers_planted():
    rng = np.random.default_rng(2)
    alpha0, alpha1 = -2.5, 0.85
    n_in = np.exp(rng.normal(5.5, 0.8, 3000)).astype(int) + 1
    ttft = np.exp(alpha0 + alpha1 * np.log(n_in + 1.0) + rng.normal(0, 0.15, 3000))
    n_out = np.exp(rng.normal(4.5, 0.5, 3000)).astype(int) + 1
    tbt = np.exp(rng.normal(-4.2, 0.25, 3000))
    d = {
        "n_in": list(n_in),
        "prefill_s": list(ttft),
        "n_out": list(n_out),
        "decode_s": list(n_out * tbt),
    }
    fit = calibrate_surrogate(d)
    assert abs(fit["alpha0"] - alpha0) < 0.1
    assert abs(fit["alpha1"] - alpha1) < 0.03
    assert abs(fit["mu_log_tbt"] + 4.2) < 0.02
    assert abs(fit["sigma_log_tbt"] - 0.25) < 0.02


def test_calibrate_surrogate_rejects_tiny_samples():
    with pytest.raises(AssertionError):
        calibrate_surrogate({"n_in": [1], "prefill_s": [0.1], "n_out": [1], "decode_s": [0.1]})


def test_moe_flag_estimates_phi():
    powers, a_series = synthetic_traces(seed=3)
    # Inject AR(1) persistence into the power noise.
    phi = 0.8
    for p in powers:
        noise = np.zeros(len(p))
        rng = np.random.default_rng(4)
        for t in range(1, len(p)):
            noise[t] = phi * noise[t - 1] + rng.normal() * 10 * np.sqrt(1 - phi**2)
        p += noise.astype(np.float32)
    res = train_config(
        powers, a_series, is_moe=True, seed=5, n_steps=30, window=64,
        batch=4, k_range=range(2, 5),
    )
    assert np.any(res.phi > 0.2), f"phi={res.phi}"
    assert np.all((res.phi >= 0) & (res.phi < 1))
