import os
import sys

# Tests are run from the `python/` directory (`make test`); make the
# `compile` package importable from the repo root too.
sys.path.insert(0, os.path.normpath(os.path.join(os.path.dirname(__file__), "..")))
