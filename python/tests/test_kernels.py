"""L1 Pallas kernels vs pure-jnp oracles (`ref.py`).

Hypothesis sweeps shapes and value ranges; assert_allclose against the
reference implementations. Pallas runs in interpret mode (CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (used by the compat shim's skip marks)

from _hypothesis_compat import given, settings, st
from numpy.testing import assert_allclose

from compile.kernels.gmm import gmm_posterior_pallas
from compile.kernels.gru import gru_cell_pallas
from compile.kernels.ref import gmm_posterior_ref, gru_cell_ref


def _rand(rng, *shape, scale=1.0):
    return rng.normal(0.0, scale, size=shape).astype(np.float32)


# ----------------------------------------------------------------------------
# GRU cell kernel
# ----------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 8, 64, 65, 100]),
    h=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gru_cell_matches_ref_across_shapes(b, h, seed):
    rng = np.random.default_rng(seed)
    hs = _rand(rng, b, h)
    gi = _rand(rng, b, 3 * h, scale=2.0)
    w = _rand(rng, h, 3 * h, scale=0.3)
    bias = _rand(rng, 3 * h)
    out_ref = np.asarray(gru_cell_ref(jnp.asarray(hs), jnp.asarray(gi), jnp.asarray(w), jnp.asarray(bias)))
    out_pal = np.asarray(gru_cell_pallas(jnp.asarray(hs), jnp.asarray(gi), jnp.asarray(w), jnp.asarray(bias)))
    assert out_pal.shape == (b, h)
    assert_allclose(out_pal, out_ref, rtol=1e-5, atol=1e-5)


def test_gru_cell_extreme_values_stay_bounded():
    # Saturated gates: outputs must stay in a GRU-reachable range.
    rng = np.random.default_rng(0)
    hs = _rand(rng, 4, 64)
    gi = _rand(rng, 4, 192, scale=50.0)  # saturate everything
    w = _rand(rng, 64, 192, scale=5.0)
    bias = _rand(rng, 192, scale=10.0)
    out = np.asarray(gru_cell_pallas(jnp.asarray(hs), jnp.asarray(gi), jnp.asarray(w), jnp.asarray(bias)))
    assert np.all(np.isfinite(out))
    # h' is a convex combination of h and tanh(...) ∈ [-1, 1]
    bound = np.maximum(np.abs(hs), 1.0) + 1e-6
    assert np.all(np.abs(out) <= bound)


def test_gru_cell_identity_when_update_gate_saturated():
    # gi z-block = +inf → z = 1 → h' = h exactly.
    h = 64
    hs = np.random.default_rng(1).normal(size=(2, h)).astype(np.float32)
    gi = np.zeros((2, 3 * h), np.float32)
    gi[:, h:2 * h] = 100.0
    w = np.zeros((h, 3 * h), np.float32)
    bias = np.zeros(3 * h, np.float32)
    out = np.asarray(gru_cell_pallas(jnp.asarray(hs), jnp.asarray(gi), jnp.asarray(w), jnp.asarray(bias)))
    assert_allclose(out, hs, rtol=0, atol=1e-6)


# ----------------------------------------------------------------------------
# GMM posterior kernel
# ----------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 7, 128, 129, 500]),
    k=st.sampled_from([1, 2, 5, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gmm_posterior_matches_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    y = rng.normal(200.0, 80.0, size=n).astype(np.float32)
    mu = np.sort(rng.uniform(50, 400, size=k)).astype(np.float32)
    sigma = rng.uniform(2, 20, size=k).astype(np.float32)
    pi = rng.dirichlet(np.ones(k)).astype(np.float32)
    out_ref = np.asarray(gmm_posterior_ref(jnp.asarray(y), jnp.asarray(pi), jnp.asarray(mu), jnp.asarray(sigma)))
    out_pal = np.asarray(gmm_posterior_pallas(jnp.asarray(y), jnp.asarray(pi), jnp.asarray(mu), jnp.asarray(sigma)))
    assert out_pal.shape == (n, k)
    assert_allclose(out_pal, out_ref, rtol=1e-5, atol=1e-6)
    assert_allclose(out_pal.sum(axis=1), np.ones(n), rtol=0, atol=1e-5)


def test_gmm_posterior_picks_nearest_component():
    y = jnp.asarray(np.array([0.0, 10.0], np.float32))
    pi = jnp.asarray(np.array([0.5, 0.5], np.float32))
    mu = jnp.asarray(np.array([0.0, 10.0], np.float32))
    sigma = jnp.asarray(np.array([1.0, 1.0], np.float32))
    post = np.asarray(gmm_posterior_pallas(y, pi, mu, sigma))
    assert post[0, 0] > 0.999
    assert post[1, 1] > 0.999


def test_gmm_posterior_far_tail_is_stable():
    # A sample 100σ from every component must not produce NaNs.
    y = jnp.asarray(np.array([1e5], np.float32))
    pi = jnp.asarray(np.array([0.3, 0.7], np.float32))
    mu = jnp.asarray(np.array([100.0, 300.0], np.float32))
    sigma = jnp.asarray(np.array([5.0, 5.0], np.float32))
    post = np.asarray(gmm_posterior_pallas(y, pi, mu, sigma))
    assert np.all(np.isfinite(post))
    assert abs(post.sum() - 1.0) < 1e-5
