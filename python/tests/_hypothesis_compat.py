"""Optional-hypothesis shim.

The build image does not always ship `hypothesis`. Importing this module
instead of `hypothesis` directly keeps the *deterministic* tests in a
module runnable everywhere: property tests decorated with the fallback
`@given(...)` are skipped individually instead of the whole module
failing collection (or being skipped wholesale by `importorskip`).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stands in for `strategies`: any attribute is a callable."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
