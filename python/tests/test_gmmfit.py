"""GMM EM + BIC + AR(1) estimation tests (mirror of the Rust EM tests so
the two implementations stay behaviorally aligned)."""

import numpy as np
import pytest

from compile.gmmfit import Gmm, estimate_ar1_phi, fit_gmm, select_k


def sample_mixture(pi, mu, sigma, n, rng):
    k = rng.choice(len(pi), size=n, p=pi)
    return rng.normal(np.asarray(mu)[k], np.asarray(sigma)[k])


def test_em_recovers_planted_mixture():
    rng = np.random.default_rng(1)
    y = sample_mixture([0.3, 0.5, 0.2], [60, 200, 350], [5, 8, 6], 8000, rng)
    g = fit_gmm(y, 3, rng)
    assert np.allclose(g.mu, [60, 200, 350], atol=3)
    assert np.allclose(g.pi, [0.3, 0.5, 0.2], atol=0.03)
    assert np.allclose(g.sigma, [5, 8, 6], atol=1.5)


def test_fit_output_sorted_by_mean():
    rng = np.random.default_rng(2)
    y = sample_mixture([0.5, 0.5], [300, 60], [10, 10], 4000, rng)
    g = fit_gmm(y, 2, rng)
    assert g.mu[0] < g.mu[1]


def test_bic_selects_true_order():
    rng = np.random.default_rng(3)
    y = sample_mixture([0.25] * 4, [50, 150, 250, 350], [8] * 4, 12_000, rng)
    g, ks, bics = select_k(y, range(1, 8), rng)
    assert g.k == 4, f"bics={bics}"
    assert bics[3] < bics[0]


def test_labels_are_posterior_argmax():
    g = Gmm(pi=np.array([0.5, 0.5]), mu=np.array([0.0, 10.0]), sigma=np.array([1.0, 1.0]))
    lab = g.labels(np.array([-1.0, 4.9, 5.1, 11.0]))
    assert list(lab) == [0, 0, 1, 1]


def test_variance_floor_prevents_collapse():
    rng = np.random.default_rng(4)
    y = np.concatenate([np.full(500, 100.0), np.full(500, 200.0)])
    g = fit_gmm(y, 2, rng)
    assert np.all(g.sigma > 0) and np.all(np.isfinite(g.sigma))


def test_rejects_insufficient_samples():
    rng = np.random.default_rng(5)
    with pytest.raises(AssertionError):
        fit_gmm(np.ones(5), 2, rng)


def test_ar1_phi_estimation_recovers_persistence():
    rng = np.random.default_rng(6)
    # One state with AR(1) noise phi=0.8, another i.i.d.
    n = 30_000
    phi = 0.8
    x = np.zeros(n)
    for t in range(1, n):
        x[t] = phi * x[t - 1] + rng.normal() * np.sqrt(1 - phi**2)
    y0 = 100.0 + 5.0 * x
    y1 = 300.0 + 5.0 * rng.standard_normal(n)
    y = np.concatenate([y0, y1])
    labels = np.concatenate([np.zeros(n, int), np.ones(n, int)])
    g = Gmm(pi=np.array([0.5, 0.5]), mu=np.array([100.0, 300.0]), sigma=np.array([5.0, 5.0]))
    phis = estimate_ar1_phi(y, labels, g)
    assert abs(phis[0] - 0.8) < 0.05, phis
    assert abs(phis[1]) < 0.05, phis


def test_ar1_phi_short_segments_default_zero():
    g = Gmm(pi=np.array([1.0]), mu=np.array([0.0]), sigma=np.array([1.0]))
    phis = estimate_ar1_phi(np.array([0.1, 0.2]), np.array([0, 0]), g)
    assert phis[0] == 0.0
