"""L2 model tests: pallas path == ref path, shapes, normalization,
pack/unpack round-trip, feature transform, bidirectional context."""

import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (used by the compat shim's skip marks)

from _hypothesis_compat import given, settings, st
from numpy.testing import assert_allclose

from compile.model import (
    HIDDEN,
    K_MAX,
    bigru_export,
    bigru_logits,
    bigru_probs,
    flat_param_count,
    init_params,
    pack_params,
    scale_features,
    unpack_params,
)


def rand_flat(seed=0):
    return jnp.asarray(init_params(np.random.default_rng(seed)))


def rand_x(b, t, seed=1):
    rng = np.random.default_rng(seed)
    a = np.maximum.accumulate(rng.integers(-2, 3, size=(b, t)).cumsum(axis=1), axis=1)
    a = np.maximum(a, 0).astype(np.float32)
    da = np.diff(a, prepend=0.0, axis=1).astype(np.float32)
    return jnp.asarray(np.stack([a, da], axis=-1))


def test_param_count_matches_design():
    assert flat_param_count() == 27_660  # DESIGN.md §6


def test_pack_unpack_roundtrip():
    flat = rand_flat(3)
    back = pack_params(unpack_params(flat))
    assert_allclose(np.asarray(back), np.asarray(flat), rtol=0, atol=0)


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2, 4]), t=st.sampled_from([1, 7, 33]), seed=st.integers(0, 1000))
def test_pallas_path_matches_ref_path(b, t, seed):
    flat = rand_flat(seed)
    x = rand_x(b, t, seed + 1)
    p_ref = np.asarray(bigru_probs(flat, x, use_pallas=False))
    p_pal = np.asarray(bigru_probs(flat, x, use_pallas=True))
    assert_allclose(p_pal, p_ref, rtol=1e-5, atol=1e-6)


def test_probs_normalized_and_shaped():
    flat = rand_flat(5)
    x = rand_x(2, 50)
    p = np.asarray(bigru_probs(flat, x))
    assert p.shape == (2, 50, K_MAX)
    assert_allclose(p.sum(-1), np.ones((2, 50)), rtol=0, atol=1e-5)
    assert np.all(p >= 0)


def test_logits_softmax_consistency():
    flat = rand_flat(6)
    x = rand_x(1, 20)
    logits = np.asarray(bigru_logits(flat, x))
    probs = np.asarray(bigru_probs(flat, x))
    e = np.exp(logits - logits.max(-1, keepdims=True))
    assert_allclose(probs, e / e.sum(-1, keepdims=True), rtol=1e-5, atol=1e-6)


def test_bidirectional_context_flows_backward():
    flat = rand_flat(7)
    x = np.asarray(rand_x(1, 10)).copy()
    p1 = np.asarray(bigru_probs(flat, jnp.asarray(x)))
    x[0, -1, 0] += 40.0
    p2 = np.asarray(bigru_probs(flat, jnp.asarray(x)))
    assert np.abs(p1[0, 0] - p2[0, 0]).sum() > 1e-7


def test_scale_features_values():
    x = jnp.asarray(np.array([[[0.0, 0.0], [1.0, 1.0], [63.0, -2.0]]], np.float32))
    s = np.asarray(scale_features(x))[0]
    assert_allclose(s[0], [0.0, 0.0], atol=1e-7)
    assert_allclose(s[1], [np.log(2.0) / 2, np.log(2.0) / 2], rtol=1e-6)
    assert_allclose(s[2], [np.log(64.0) / 2, -np.log(3.0) / 2], rtol=1e-6)


def test_export_wrapper_single_sequence():
    flat = rand_flat(8)
    x = rand_x(1, 16)[0]
    out = np.asarray(bigru_export(flat, x))
    assert out.shape == (16, K_MAX)
    full = np.asarray(bigru_probs(flat, x[None], use_pallas=True))[0]
    assert_allclose(out, full, rtol=1e-6, atol=1e-7)


def test_export_lowering_produces_hlo_text():
    import jax

    from compile.aot import to_hlo_text

    p_spec = jax.ShapeDtypeStruct((flat_param_count(),), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((32, 2), jnp.float32)
    text = to_hlo_text(jax.jit(bigru_export).lower(p_spec, x_spec))
    assert text.startswith("HloModule")
    assert "f32[32,2]" in text
    assert f"f32[{flat_param_count()}]" in text
