//! Facility-generation throughput: servers × hours of 250 ms trace per
//! wall-second — the headline L3 performance number (EXPERIMENTS.md §Perf).

use powertrace_sim::aggregate::Topology;
use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;

fn main() {
    section("facility generation throughput");
    let mut gen = match Generator::pjrt().or_else(|_| Generator::native()) {
        Ok(g) => g,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let id = gen.store.manifest.configs[0].clone();
    let mut spec = ScenarioSpec::default_poisson(&id, 1.0);
    spec.topology = Topology { rows: 1, racks_per_row: 3, servers_per_rack: 4 };
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.workload = WorkloadSpec::Poisson { rate: 1.0 };
    spec.horizon_s = 900.0;

    let b = Bench { budget: std::time::Duration::from_secs(4), max_iters: 5 };
    let dt = 0.25;
    let r = b.run("facility(12 servers × 15min @250ms)", || {
        gen.facility(&spec, dt, 0).unwrap().it_series().len()
    });
    let server_seconds = spec.topology.n_servers() as f64 * spec.horizon_s;
    println!(
        "  throughput: {:.0}x realtime per core (server-seconds generated / wall-second)",
        server_seconds / r.mean.as_secs_f64()
    );
}
