//! Facility-generation throughput: servers × hours of 250 ms trace per
//! wall-second — the headline L3 performance number (EXPERIMENTS.md §Perf).
//!
//! Measures the sequential per-server path (`max_batch = 1`, the
//! pre-batching pipeline) against the rack-batched GEMM engine on the same
//! scenario, prints the speedup, and records both as machine-readable
//! entries in `BENCH_facility.json` so the perf trajectory is tracked
//! across PRs. Falls back to a synthetic random-weight artifact store at
//! production geometry (H=64, K=12) when `make artifacts` hasn't run —
//! the compute shape is identical, so throughput numbers stay meaningful.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::benchutil::{section, write_bench_json, Bench, BenchEntry};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::testutil::synth_generator;
use std::path::Path;
use std::time::Duration;

fn main() {
    section("facility generation throughput (sequential vs rack-batched)");
    let (mut gen, id) = match Generator::pjrt().or_else(|_| Generator::native()) {
        Ok(g) => {
            let id = g.store.manifest.configs[0].clone();
            (g, id)
        }
        Err(_) => {
            println!("  (no artifact store; using a synthetic random-weight store, H=64 K=12)");
            let (g, ids) = synth_generator("bench_facility", 64, 12, 1, 99)
                .expect("synthetic artifact store");
            let id = ids[0].clone();
            (g, id)
        }
    };
    let mut spec = ScenarioSpec::default_poisson(&id, 1.0);
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 16 };
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.workload = WorkloadSpec::Poisson { rate: 1.0 };
    spec.horizon_s = 900.0;
    if let Err(e) = gen.prepare_for(&spec) {
        println!("skipped (config not preparable): {e:#}");
        return;
    }

    let dt = 0.25;
    let n_servers = spec.topology.n_servers() as f64;
    let server_seconds = n_servers * spec.horizon_s;
    let b = Bench::budgeted(Duration::from_secs(4), 5);
    let seq = b.run("facility(32 srv × 15min) sequential", || {
        gen.facility_shared_batched(&spec, dt, 0, 1).unwrap().it_series().len()
    });
    let bat = b.run("facility(32 srv × 15min) rack-batched", || {
        gen.facility_shared_batched(&spec, dt, 0, 0).unwrap().it_series().len()
    });
    // Windowed streaming engine on the same scenario: same rack batching,
    // bit-identical output, bounded memory — the throughput delta is the
    // price of the extra backward prologue + per-window feature rebuilds.
    let win = b.run("facility(32 srv × 15min) windowed(60s)", || {
        let mut samples = 0usize;
        gen.facility_shared_windowed(&spec, dt, 60.0, 0, 0, |acc| {
            samples += acc.window_len();
            Ok(())
        })
        .unwrap();
        samples
    });
    let sps_seq = n_servers / seq.mean.as_secs_f64();
    let sps_bat = n_servers / bat.mean.as_secs_f64();
    let sps_win = n_servers / win.mean.as_secs_f64();
    println!(
        "  sequential: {:.1} servers/s ({:.0}x realtime total), batched: {:.1} servers/s \
         ({:.0}x realtime total) → speedup {:.2}x; windowed streaming: {:.1} servers/s \
         ({:.2}x of batched)",
        sps_seq,
        server_seconds / seq.mean.as_secs_f64(),
        sps_bat,
        server_seconds / bat.mean.as_secs_f64(),
        seq.mean.as_secs_f64() / bat.mean.as_secs_f64(),
        sps_win,
        bat.mean.as_secs_f64() / win.mean.as_secs_f64(),
    );
    // With `--features simd` the batched engine dispatches to the f32x8
    // kernels; suffix the entry names so a scalar run and a SIMD run of
    // the same binary merge into one BENCH_facility.json side by side
    // (write_bench_json merges by name).
    let sfx = if cfg!(feature = "simd") { "_simd" } else { "" };
    if let Err(e) = write_bench_json(
        Path::new("BENCH_facility.json"),
        &[
            BenchEntry::from_result(&format!("facility_sequential{sfx}"), &seq, Some(n_servers)),
            BenchEntry::from_result(&format!("facility_batched{sfx}"), &bat, Some(n_servers)),
            BenchEntry::from_result(&format!("facility_windowed{sfx}"), &win, Some(n_servers)),
        ],
    ) {
        println!("  (BENCH_facility.json not written: {e:#})");
    } else {
        println!("  wrote BENCH_facility.json");
    }
}
