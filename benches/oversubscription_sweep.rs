//! Fig 11 regeneration bench: rack-pool generation + P95 row-power sweep
//! (scaled down; `powertrace repro fig11` runs the full version).

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::experiments::{common::EvalCtx, oversub};
use powertrace_sim::util::cli::Args;

fn main() {
    section("fig11: oversubscription sweep (scaled)");
    let args = Args::parse([
        "--fast".to_string(),
        "--backend".into(), "native".into(),
        "--max-racks".into(), "10".into(),
        "--horizon-h".into(), "0.25".into(),
        "--limit-kw".into(), "120".into(),
        "--dt".into(), "2".into(),
    ]);
    // Validate artifacts exist before timing.
    if EvalCtx::new(&args).is_err() {
        println!("skipped (artifacts not built?)");
        return;
    }
    let b = Bench { budget: std::time::Duration::from_secs(2), max_iters: 2 };
    b.run("oversub_sweep(10 racks × 15min @2s)", || {
        oversub::run(&args).unwrap();
    });
}
