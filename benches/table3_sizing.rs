//! Table 3 regeneration bench: a scaled facility study (diurnal workload)
//! timed end-to-end, printing the sizing rows per method.

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::experiments::{common::EvalCtx, facility};
use powertrace_sim::util::cli::Args;

fn main() {
    section("table3: facility sizing study (scaled)");
    let args = Args::parse([
        "--fast".to_string(),
        "--backend".into(), "native".into(),
        "--servers".into(), "12".into(),
        "--horizon-h".into(), "2".into(),
        "--dt".into(), "2".into(),
    ]);
    let mut ctx = match EvalCtx::new(&args) {
        Ok(c) => c,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let b = Bench { budget: std::time::Duration::from_secs(2), max_iters: 3 };
    b.run("facility_study(12 servers × 2h @2s)", || {
        let study = facility::generate(&mut ctx, &args).unwrap();
        let site = study.ours.facility_series(study.pue);
        let st = powertrace_sim::metrics::PlanningStats::compute(&site, 2.0, 900.0).expect("stats");
        println!(
            "  ours peak {:.3} MW avg {:.3} MW PAR {:.2} ramp {:.3} MW (TDP {:.3} MW)",
            st.peak_w / 1e6,
            st.avg_w / 1e6,
            st.peak_to_average,
            st.max_ramp_w / 1e6,
            study.tdp_w_site / 1e6
        );
        st.peak_w
    });
}
