//! Sweep-engine throughput: scenarios per wall-second with shared
//! per-configuration artifacts — the multi-run counterpart of the
//! `facility_generation` bench (EXPERIMENTS.md §Perf).
//!
//! Also measures the shared-prepare effect directly: `Generator::prepare`
//! on a warm cache must be effectively free, which is what lets a grid of
//! N cells avoid N artifact loads + classifier builds. Cell throughput is
//! recorded to `BENCH_facility.json` (servers/sec across the whole grid)
//! alongside the facility-generation entries.

use powertrace_sim::api::{self, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::benchutil::{section, write_bench_json, Bench, BenchEntry};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::scenarios::SweepGrid;
use powertrace_sim::testutil::synth_generator;
use std::path::Path;
use std::time::Duration;

fn main() {
    section("sweep: multi-scenario throughput (shared artifacts)");
    let (mut gen, ids) = match Generator::pjrt().or_else(|_| Generator::native()) {
        Ok(g) => {
            let ids = g.store.manifest.configs.clone();
            (g, ids)
        }
        Err(_) => {
            println!("  (no artifact store; using a synthetic random-weight store, H=64 K=12)");
            let (g, ids) =
                synth_generator("bench_sweep", 64, 12, 2, 101).expect("synthetic artifact store");
            (g, ids)
        }
    };
    if ids.is_empty() {
        println!("skipped (artifact manifest lists no configs)");
        return;
    }
    // 8 cells × 4 servers × 2 min @250ms — small enough to iterate.
    let grid = SweepGrid::example("bench", &ids, 120.0);
    let n_cells = grid.n_cells();
    let total_servers: usize = grid.expand().iter().map(|c| c.spec.topology.n_servers()).sum();

    let b = Bench::budgeted(Duration::from_secs(6), 5);
    let req = RunRequest::new(RunSpec::Sweep(grid.clone()));
    let r = b.run(&format!("api::execute({n_cells} cells, {total_servers} servers)"), || {
        match api::execute(&mut gen, &req, None).unwrap() {
            RunOutcome::Sweep(report) => report.cells.len(),
            _ => unreachable!(),
        }
    });
    let per_cell = r.mean.as_secs_f64() / n_cells as f64;
    println!(
        "  → {:.3} s/cell ({:.1} cells/s, {:.1} servers/s across the grid)",
        per_cell,
        1.0 / per_cell.max(1e-9),
        total_servers as f64 / r.mean.as_secs_f64()
    );
    // Keep scalar and `--features simd` runs as separate entries so one
    // BENCH_facility.json can carry the before/after pair.
    let entry_name = if cfg!(feature = "simd") { "sweep_grid_simd" } else { "sweep_grid" };
    if let Err(e) = write_bench_json(
        Path::new("BENCH_facility.json"),
        &[BenchEntry::from_result(entry_name, &r, Some(total_servers as f64))],
    ) {
        println!("  (BENCH_facility.json not written: {e:#})");
    }

    // Warm-cache prepare: the per-config state the sweep shares.
    let id = ids[0].clone();
    gen.prepare(&id).unwrap();
    b.run("prepare(warm cache)", || gen.prepare(&id).unwrap().art.k);
}
