//! Sweep-engine throughput: scenarios per wall-second with shared
//! per-configuration artifacts — the multi-run counterpart of the
//! `facility_generation` bench (EXPERIMENTS.md §Perf).
//!
//! Also measures the shared-prepare effect directly: `Generator::prepare`
//! on a warm cache must be effectively free, which is what lets a grid of
//! N cells avoid N artifact loads + classifier builds.

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::scenarios::{run_sweep, SweepGrid, SweepOptions};

fn main() {
    section("sweep: multi-scenario throughput (shared artifacts)");
    let mut gen = match Generator::pjrt().or_else(|_| Generator::native()) {
        Ok(g) => g,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let ids = gen.store.manifest.configs.clone();
    if ids.is_empty() {
        println!("skipped (artifact manifest lists no configs)");
        return;
    }
    // 8 cells × 4 servers × 2 min @250ms — small enough to iterate.
    let grid = SweepGrid::example("bench", &ids, 120.0);
    let n_cells = grid.n_cells();

    let b = Bench { budget: std::time::Duration::from_secs(6), max_iters: 5 };
    let opts = SweepOptions::default();
    let r = b.run(&format!("run_sweep({n_cells} cells × 8 servers × 2min)"), || {
        run_sweep(&mut gen, &grid, &opts).unwrap().cells.len()
    });
    let per_cell = r.mean.as_secs_f64() / n_cells as f64;
    println!("  → {:.3} s/cell ({:.1} cells/s)", per_cell, 1.0 / per_cell.max(1e-9));

    // Warm-cache prepare: the per-config state the sweep shares.
    let id = ids[0].clone();
    gen.prepare(&id).unwrap();
    b.run("prepare(warm cache)", || gen.prepare(&id).unwrap().art.k);
}
