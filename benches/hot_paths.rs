//! Hot-path micro-benchmarks — the instruments for the §Perf optimization
//! pass (EXPERIMENTS.md §Perf). Measures every stage of the per-server
//! pipeline separately plus the PJRT chunk execution.

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::classifier::{NativeBiGru, StateClassifier};
use powertrace_sim::classifier::native::BiGruWeights;
use powertrace_sim::coordinator::Generator;
use powertrace_sim::states::{fit_gmm, EmOptions};
use powertrace_sim::surrogate::{features_from_intervals, simulate_queue, SurrogateParams};
use powertrace_sim::synth::{sample_power, sample_states, SynthMode};
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{poisson_arrivals, LengthSampler};

fn main() {
    let b = Bench::default();
    section("hot paths: per-server pipeline stages (10-min trace @250ms)");

    let params = SurrogateParams {
        alpha0: -2.0,
        alpha1: 0.8,
        sigma_ttft: 0.2,
        mu_log_tbt: -4.0,
        sigma_log_tbt: 0.2,
    };
    let lengths = LengthSampler::fixed(512, 256);
    let mut rng = Rng::new(1);
    let sched = poisson_arrivals(2.0, 600.0, &lengths, &mut rng);
    let n_steps = 2400;

    b.run("surrogate_queue(1200 req)", || {
        let mut r = Rng::new(2);
        simulate_queue(&sched, &params, 64, &mut r)
    });
    let mut r = Rng::new(2);
    let intervals = simulate_queue(&sched, &params, 64, &mut r);
    b.run("features(2400 steps)", || features_from_intervals(&intervals, n_steps, 0.25));
    let feats = features_from_intervals(&intervals, n_steps, 0.25);
    let x = feats.interleaved();

    // Native classifier.
    let mut wrng = Rng::new(3);
    let n = powertrace_sim::classifier::N_PARAMS;
    let flat: Vec<f32> = (0..n).map(|_| (wrng.normal() * 0.1) as f32).collect();
    let native = NativeBiGru::new(BiGruWeights::new(64, 12, flat.clone()).unwrap());
    b.run("classifier_native(2400 steps)", || native.probs(&x, n_steps).unwrap());

    // Batched classifier: 16 lanes in lockstep (one rack) vs 16 sequential
    // calls — the kernel-level view of the §Perf GEMV→GEMM win.
    let lanes = 16usize;
    let refs: Vec<&[f32]> = (0..lanes).map(|_| x.as_slice()).collect();
    let mut arena = powertrace_sim::classifier::ScratchArena::new();
    let mut batched_out = Vec::new();
    b.run("classifier_native_batched(2400 × 16 lanes)", || {
        native.probs_batch_into(&refs, n_steps, &mut arena, &mut batched_out).unwrap();
        batched_out.len()
    });

    // Sampling.
    let probs = native.probs(&x, n_steps).unwrap();
    b.run("sample_states+power(2400)", || {
        let mut r = Rng::new(4);
        let states = sample_states(&probs, 12, &mut r);
        let dict = powertrace_sim::states::StateDictionary {
            pi: vec![1.0 / 12.0; 12],
            mu: (0..12).map(|i| 100.0 + 50.0 * i as f64).collect(),
            sigma: vec![8.0; 12],
            phi: vec![0.0; 12],
            y_min: 50.0,
            y_max: 800.0,
        };
        sample_power(&states, &dict, SynthMode::Iid, &mut r)
    });

    // GMM EM (Fig 4 substrate).
    let mut grng = Rng::new(5);
    let ys: Vec<f32> = (0..10_000)
        .map(|i| grng.normal_ms(if i % 3 == 0 { 100.0 } else { 300.0 }, 10.0) as f32)
        .collect();
    b.run("gmm_em_fit(k=8, 10k samples)", || {
        let mut r = Rng::new(6);
        fit_gmm(&ys, 8, &EmOptions { n_init: 1, max_iters: 40, ..Default::default() }, &mut r)
            .unwrap()
    });

    // PJRT path (needs artifacts).
    section("PJRT artifact execution");
    match Generator::pjrt() {
        Ok(mut gen) => {
            let id = gen.store.manifest.configs[0].clone();
            let art = gen.config(&id).unwrap();
            let cls = gen.classifier(&art).unwrap();
            b.run("classifier_pjrt(2400 steps, 512-chunks)", || {
                cls.probs(&x, n_steps).unwrap()
            });
            b.run("full_server_trace_pjrt(10min)", || {
                let mut r = Rng::new(7);
                gen.server_trace(&art, &cls, &sched, 600.0, 0.25, &mut r).unwrap()
            });
        }
        Err(e) => println!("pjrt benches skipped: {e:#}"),
    }
}
