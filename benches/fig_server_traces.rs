//! Fig 1 / Fig 6 regeneration bench: per-server synthesis against a
//! held-out measured trace, for ours and the LUT baseline.

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::experiments::common::{EvalCtx, ACF_MAX_LAG};
use powertrace_sim::metrics::fidelity;
use powertrace_sim::util::cli::Args;

fn main() {
    section("fig1/fig6: server trace synthesis vs measured");
    let args = Args::parse(["--backend".to_string(), "native".into()]);
    let mut ctx = match EvalCtx::new(&args) {
        Ok(c) => c,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let id = ctx.config_ids()[0].clone();
    let art = ctx.config(&id).unwrap();
    let cls = ctx.classifier(&id).unwrap();
    let measured = ctx.gen.store.load_all_measured(&id).unwrap();
    let m = &measured[measured.len() / 2];

    let b = Bench::default();
    b.run(&format!("synth_like({id}, {} steps)", m.power_w.len()), || {
        ctx.synth_like(&art, &cls, m, 1).unwrap()
    });
    b.run("lut_like(same trace)", || ctx.lut_like(&art, m, 1).unwrap());

    let syn = ctx.synth_like(&art, &cls, m, 1).unwrap();
    let f = fidelity(&m.power_w, &syn, ACF_MAX_LAG);
    println!(
        "  fidelity: KS {:.2} ACF R² {} NRMSE {:.2} |ΔE| {:.1}%",
        f.ks,
        f.acf_r2.map(|v| format!("{v:.2}")).unwrap_or("–".into()),
        f.nrmse,
        f.delta_energy.abs() * 100.0
    );
}
