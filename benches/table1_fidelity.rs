//! End-to-end bench for Table 1: held-out fidelity evaluation across all
//! trained configurations (2 seeds in bench mode). Prints the table rows
//! alongside the timing so the bench doubles as a regeneration harness.

use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::experiments::{common::EvalCtx, table1};
use powertrace_sim::util::cli::Args;

fn main() {
    section("table1: held-out fidelity (all configs)");
    let args = Args::parse(["--fast".to_string(), "--backend".into(), "native".into()]);
    let mut ctx = match EvalCtx::new(&args) {
        Ok(c) => c,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let b = Bench { budget: std::time::Duration::from_secs(1), max_iters: 3 };
    let mut rows = Vec::new();
    b.run("table1_compute(all configs, 2 seeds)", || {
        rows = table1::compute(&mut ctx).unwrap();
        rows.len()
    });
    for r in &rows {
        println!(
            "  {:<12} KS {:.2}±{:.2}  ACF R² {:.2}±{:.2}  NRMSE {:.2}±{:.2}  |ΔE| {:.1}±{:.1}%",
            r.model, r.ks.0, r.ks.1, r.acf_r2.0, r.acf_r2.1, r.nrmse.0, r.nrmse.1, r.de_pct.0, r.de_pct.1
        );
    }
}
