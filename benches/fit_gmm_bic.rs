//! Fig 4 regeneration bench: EM + BIC model selection over pooled measured
//! power (the Rust states substrate).

use powertrace_sim::artifacts::ArtifactStore;
use powertrace_sim::benchutil::{section, Bench};
use powertrace_sim::states::{select_k, EmOptions};
use powertrace_sim::util::rng::Rng;

fn main() {
    section("fig4: GMM EM + BIC selection");
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            println!("skipped (artifacts not built?): {e:#}");
            return;
        }
    };
    let id = store.manifest.configs[0].clone();
    let measured = store.load_all_measured(&id).unwrap();
    let pooled: Vec<f32> = measured.iter().flat_map(|m| m.power_w.iter().copied()).collect();
    println!("  pooled {} samples from {id}", pooled.len());

    let b = Bench { budget: std::time::Duration::from_secs(3), max_iters: 5 };
    let opts = EmOptions { n_init: 1, max_iters: 50, ..Default::default() };
    b.run("select_k(1..=10)", || {
        let mut rng = Rng::new(4);
        let (_, curve) = select_k(&pooled, 1..=10, &opts, &mut rng).unwrap();
        curve.best_k
    });
    let mut rng = Rng::new(4);
    let (gmm, curve) = select_k(&pooled, 1..=10, &opts, &mut rng).unwrap();
    println!("  selected K = {} (means {:?})", curve.best_k, gmm.mu.iter().map(|m| m.round()).collect::<Vec<_>>());
}
