//! Peak shaving at the interconnection: the paper's "power modulation"
//! use case, end to end. Three phase-staggered diurnal facilities compose
//! into one site profile; the same site is then re-run with a net-load
//! overlay — a site battery shaving toward a threshold, an interconnection
//! cap clipping the residual, and a PV plant offsetting daytime load — and
//! the two utility-facing summaries are compared: how much peak the
//! overlay buys, what it cost in battery cycles, and whether the cap was
//! ever violated.
//!
//!     cargo run --release --example peak_shaving -- [n_facilities] [battery_kwh]
//!
//! Defaults: 3 facilities staggered 4 h, 24 h horizon, dt 1 s, 1 h
//! lockstep windows, on a synthetic random-weight artifact store
//! (`testutil::synth_generator`), so it runs without `make artifacts`.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, WorkloadSpec};
use powertrace_sim::export::DirSink;
use powertrace_sim::site::{OverlaySpec, SiteSpec};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TrafficMode;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_facilities: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let battery_kwh: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60.0);

    let (mut gen, ids) = synth_generator("peak_shaving", 16, 6, 1, 19)?;
    let mut base = ScenarioSpec::default_poisson(&ids[0], 0.5);
    base.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 8 };
    base.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.35,
        mode: TrafficMode::SharedIntensity,
    };
    base.horizon_s = 24.0 * 3600.0;
    base.seed = 3;

    let spec = SiteSpec::staggered("shaved_site", &base, n_facilities, 4.0);
    let options = RunOptions::defaults_for(RunKind::Site).with_dt(1.0).with_window(3600.0);

    // Baseline: the raw composed profile (PR-4 path, overlay-free).
    let req = RunRequest { spec: RunSpec::Site(spec.clone()), options: options.clone() };
    let RunOutcome::Site(baseline) = api::execute(&mut gen, &req, None)? else { unreachable!() };
    let raw_peak = baseline.site.stats.peak_w;

    // Overlay run: battery shaves toward 85 % of the raw peak, the cap
    // clips anything the battery cannot hold at 92 %, and a PV plant
    // sized at a quarter of the peak offsets daytime load. Stage order is
    // the spec: shave first, clip the residual, then subtract PV.
    let threshold_w = 0.85 * raw_peak;
    let cap_w = 0.92 * raw_peak;
    let mut shaved = spec.clone();
    shaved.overlays = vec![
        OverlaySpec::Battery {
            capacity_kwh: battery_kwh,
            power_w: 0.2 * raw_peak,
            efficiency: 0.9,
            threshold_w,
            initial_soc_frac: 0.5,
        },
        OverlaySpec::Cap { cap_w },
        OverlaySpec::Pv { peak_w: 0.25 * raw_peak, peak_hour: 13.0, daylight_h: 12.0 },
    ];
    let out_dir = std::env::temp_dir().join("powertrace_peak_shaving");
    let req = RunRequest { spec: RunSpec::Site(shaved), options };
    let sink = DirSink::new(&out_dir);
    let RunOutcome::Site(report) = api::execute(&mut gen, &req, Some(&sink))? else {
        unreachable!()
    };
    let overlay = report.site.overlay.expect("overlay chain ran");

    println!(
        "site '{}': {n_facilities} facilities, {} servers, 24 h, battery {battery_kwh} kWh\n",
        spec.name,
        spec.n_servers()
    );
    println!("-- baseline (raw composed load) --");
    print!("{}", baseline.summary_table());
    println!("\n-- with overlay (battery @{threshold_w:.0} W, cap @{cap_w:.0} W, PV) --");
    print!("{}", report.summary_table());
    println!(
        "\npeak {:.3} MW -> {:.3} MW ({:.1} % shaved) | battery {:.2} cycles | \
         cap violated {:.0} s | PV offset {:.1} kWh",
        raw_peak / 1e6,
        overlay.net_peak_w / 1e6,
        100.0 * overlay.shaved_peak_w / raw_peak,
        overlay.battery_cycles,
        overlay.cap_violation_s,
        overlay.pv_offset_kwh,
    );
    println!("wrote site_load.csv + site_summary.csv under {}", out_dir.display());

    // The planning invariants the overlay engine guarantees.
    anyhow::ensure!(overlay.net_peak_w <= cap_w, "net peak above the interconnection cap");
    anyhow::ensure!(overlay.net_peak_w <= overlay.raw_peak_w, "overlay raised the peak");
    anyhow::ensure!(
        overlay.raw_peak_w.to_bits() == raw_peak.to_bits(),
        "overlay changed the raw composed series"
    );
    anyhow::ensure!(
        report.site.stats.peak_w <= cap_w * (1.0 + 1e-6),
        "exported net series exceeds the cap beyond f32 rounding"
    );
    Ok(())
}
