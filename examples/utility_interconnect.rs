//! Utility-facing interconnection study (paper §5.1): an operator shares a
//! *scenario file* and the resulting aggregate load shape with the utility
//! — never raw serving telemetry. The utility can stress-test traffic
//! assumptions by re-running with modified scenarios.
//!
//!     cargo run --release --example utility_interconnect

use powertrace_sim::aggregate::{resample, Topology};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::metrics::{max_ramp, percentile, PlanningStats};
use powertrace_sim::workload::TrafficMode;

fn main() -> anyhow::Result<()> {
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(_) => Generator::native()?,
    };

    // The operator authors a scenario file (this is the entire disclosure
    // surface: topology, hardware class, and a traffic envelope).
    let mut spec = ScenarioSpec::default_poisson("llama70b_h100_tp8", 0.5);
    spec.topology = Topology { rows: 2, racks_per_row: 3, servers_per_rack: 4 };
    spec.server_config = ServerAssignment::Uniform("llama70b_h100_tp8".into());
    spec.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.35,
        mode: TrafficMode::SharedIntensity, // utilities often assume correlated demand
    };
    spec.horizon_s = 4.0 * 3600.0;
    let scenario_path = std::env::temp_dir().join("interconnect_scenario.json");
    spec.save(&scenario_path)?;
    println!("scenario written to {} (the shareable artifact)", scenario_path.display());

    // Base case and a stress case (+50% traffic) — the counterfactual
    // analysis §5.1 describes.
    for (name, scale) in [("base", 1.0f64), ("stress +50% traffic", 1.5)] {
        let mut s = ScenarioSpec::load(&scenario_path)?;
        if let WorkloadSpec::Diurnal { ref mut base_rate, .. } = s.workload {
            *base_rate *= scale;
        }
        let dt = 1.0;
        let run = gen.facility(&s, dt, 0)?;
        let site = run.facility_series();
        let stats = PlanningStats::compute(&site, dt, 900.0)?;
        let shape_15m = resample(&site, dt, 900.0)?;
        println!("-- {name} --");
        println!(
            "  peak {:.3} MW | P95 {:.3} MW | avg {:.3} MW | 15-min ramp {:.3} MW | load factor {:.2}",
            stats.peak_w / 1e6,
            percentile(&site, 95.0)? / 1e6,
            stats.avg_w / 1e6,
            max_ramp(&site, dt, 900.0)? / 1e6,
            stats.load_factor,
        );
        println!("  15-min load shape points: {}", shape_15m.len());
    }
    println!("(raw serving telemetry — prompts, batching, per-request timing — never leaves the operator)");
    Ok(())
}
