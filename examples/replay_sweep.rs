//! Replay-axis sweep: re-drive the checked-in request trace
//! (`data/traces/sample_requests.csv`) through the sweep engine three
//! ways — verbatim, with per-server random phase offsets (paper §4.4),
//! and as a token-level workload that resamples the trace's
//! `(n_in, n_out)` pairs onto a fresh Poisson clock. All cells share one
//! parsed copy of the trace through the generator's per-path replay
//! cache, which the run asserts at the end.
//!
//!     cargo run --release --example replay_sweep
//!
//! Runs on a synthetic random-weight artifact store (no `make artifacts`
//! needed). Writes the grid + summary under `out/replay_sweep/`.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ServerAssignment, WorkloadSpec};
use powertrace_sim::scenarios::{GridDefaults, SweepGrid};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TokenLengths;

fn main() -> anyhow::Result<()> {
    let trace = "data/traces/sample_requests.csv".to_string();
    anyhow::ensure!(
        std::path::Path::new(&trace).exists(),
        "run from the repository root: {trace} not found"
    );
    let (mut gen, ids) = synth_generator("replay_sweep", 16, 6, 1, 19)?;

    // The replay axis: the same recorded demand, phase-decorrelated, and
    // re-shaped through the token engine's batch/budget packing.
    let grid = SweepGrid {
        name: "replay_sweep".into(),
        defaults: GridDefaults { horizon_s: 600.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Replay { path: trace.clone(), offset_s: 0.0 },
            WorkloadSpec::Replay { path: trace.clone(), offset_s: 120.0 },
            WorkloadSpec::Token {
                rate: 1.0,
                lengths: TokenLengths::Empirical { path: trace.clone() },
                max_batch: 8,
                token_budget: 8192,
            },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 4 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![0, 1],
    };
    println!("grid '{}': {} cells off one recorded trace\n", grid.name, grid.n_cells());

    let req = RunRequest::new(RunSpec::Sweep(grid.clone()));
    let RunOutcome::Sweep(report) = api::execute(&mut gen, &req, None)? else { unreachable!() };
    print!("{}", report.summary_table());

    let out = std::path::Path::new("out/replay_sweep");
    report.write(out)?;
    println!("\nwrote {} cells + summary.csv under {}", report.cells.len(), out.display());

    // Every cell re-reads the same path; the cache must hold one entry.
    anyhow::ensure!(
        gen.cached_replay_paths() == 1,
        "expected one parsed trace, got {}",
        gen.cached_replay_paths()
    );
    Ok(())
}
