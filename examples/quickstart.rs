//! Quickstart: generate a server-level LLM-inference power trace and print
//! planner-facing statistics.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (trains per-configuration models once).

use powertrace_sim::coordinator::Generator;
use powertrace_sim::metrics::{acf, PlanningStats};
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{poisson_arrivals, LengthSampler};

fn main() -> anyhow::Result<()> {
    // 1. Open the generator. `pjrt()` executes the AOT-compiled BiGRU
    //    artifact through the XLA PJRT CPU client; `native()` is the
    //    pure-Rust fallback with identical numerics.
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(e) => {
            eprintln!("pjrt unavailable ({e:#}); using native backend");
            Generator::native()?
        }
    };

    // 2. Pick a serving configuration from the measured campaign.
    let art = gen.config("llama70b_a100_tp8")?;
    let cls = gen.classifier(&art)?;

    // 3. Describe the workload: Poisson arrivals, ShareGPT-like lengths.
    let profile = gen.cat.datasets["sharegpt"].clone();
    let lengths = LengthSampler::from_profile(&profile, 1.0);
    let mut rng = Rng::new(7);
    let horizon_s = 600.0;
    let schedule = poisson_arrivals(1.0, horizon_s, &lengths, &mut rng);
    println!("workload: {} requests over {horizon_s} s", schedule.len());

    // 4. Generate the power trace at the paper's 250 ms resolution.
    let trace = gen.server_trace(&art, &cls, &schedule, horizon_s, 0.25, &mut rng)?;

    // 5. Planner-facing stats.
    let stats = PlanningStats::compute(&trace.power_w, 0.25, 60.0)?;
    println!(
        "server power: peak {:.0} W, avg {:.0} W, peak-to-average {:.2}, max 1-min ramp {:.0} W",
        stats.peak_w, stats.avg_w, stats.peak_to_average, stats.max_ramp_w
    );
    let rho = acf(&trace.power_w, 4);
    println!("autocorrelation ρ(1..4) = {:.2} {:.2} {:.2} {:.2}", rho[1], rho[2], rho[3], rho[4]);
    println!(
        "occupancy: max A_t = {:.0}, mean A_t = {:.1}",
        trace.a.iter().cloned().fold(0.0f32, f32::max),
        trace.a.iter().sum::<f32>() / trace.a.len() as f32
    );
    Ok(())
}
