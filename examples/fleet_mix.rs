//! Heterogeneous fleet: a hall mixing dense Llama racks with MoE gpt-oss
//! racks (paper §5.2 "model mix evolution and hardware refresh" — adding a
//! model/accelerator only needs its per-configuration artifact).
//!
//!     cargo run --release --example fleet_mix

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::metrics::{coefficient_of_variation, PlanningStats};

fn main() -> anyhow::Result<()> {
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(_) => Generator::native()?,
    };

    // Alternate racks between a dense A100 deployment and an H100 MoE one.
    let mix = vec![
        "llama70b_a100_tp8".to_string(),
        "gptoss120b_h100_tp4".to_string(),
    ];
    let mut spec = ScenarioSpec::default_poisson(&mix[0], 0.5);
    spec.topology = Topology { rows: 2, racks_per_row: 4, servers_per_rack: 2 };
    spec.server_config = ServerAssignment::PerRack(mix.clone());
    spec.workload = WorkloadSpec::Poisson { rate: 0.75 };
    spec.horizon_s = 1800.0;
    spec.seed = 5;

    let dt = 0.25;
    let run = gen.facility(&spec, dt, 0)?;
    let site = run.facility_series();
    let stats = PlanningStats::compute(&site, dt, 60.0)?;
    println!(
        "mixed hall ({} servers: {}): peak {:.1} kW avg {:.1} kW PAR {:.2}",
        spec.topology.n_servers(),
        mix.join(" + "),
        stats.peak_w / 1e3,
        stats.avg_w / 1e3,
        stats.peak_to_average
    );

    // Compare rack-level behaviour of the two technologies.
    for rack in 0..2 {
        let series = run.acc.rack_series(rack);
        let s = PlanningStats::compute(&series, dt, 60.0)?;
        let cfg = &mix[rack % mix.len()];
        println!(
            "  rack {rack} ({cfg}): peak {:.1} kW avg {:.1} kW CoV {:.3}",
            s.peak_w / 1e3,
            s.avg_w / 1e3,
            coefficient_of_variation(&series)?
        );
    }
    println!("(MoE racks show stronger within-state power persistence — AR(1) synthesis)");
    Ok(())
}
