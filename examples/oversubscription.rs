//! Oversubscription: how many racks fit under a row power limit when
//! provisioning with generated traces instead of nameplate TDP
//! (paper §4.4 / Fig 11, scaled down for a quick run).
//!
//!     cargo run --release --example oversubscription

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::metrics::percentile;
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::TrafficMode;

fn main() -> anyhow::Result<()> {
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(_) => Generator::native()?,
    };
    let id = "llama70b_a100_tp8";
    let art = gen.config(id)?;
    let cls = gen.classifier(&art)?;
    let cfg = gen.cat.config(id)?.clone();

    let limit_kw = 300.0;
    let servers_per_rack = 4;
    let max_racks = 40;
    let horizon_s = 3600.0;
    let dt = 1.0;

    let rack_tdp_kw = gen.cat.server_nameplate_w(&cfg) * servers_per_rack as f64 / 1e3;
    let nameplate_racks = (limit_kw / rack_tdp_kw).floor() as usize;
    println!("row limit {limit_kw} kW; rack nameplate {rack_tdp_kw:.1} kW → {nameplate_racks} racks by TDP");

    let mut spec = ScenarioSpec::default_poisson(id, 0.5);
    spec.horizon_s = horizon_s;
    spec.server_config = ServerAssignment::Uniform(id.into());
    spec.topology = Topology { rows: 1, racks_per_row: max_racks, servers_per_rack };
    spec.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 0.5, // evaluate at peak-demand hours
        burst_sigma: 0.35,
        mode: TrafficMode::Independent,
    };

    let n_steps = (horizon_s / dt) as usize;
    let base_rng = Rng::new(3);
    let mut row = vec![0.0f64; n_steps];
    let mut max_ok = 0;
    for rack in 0..max_racks {
        for srv in 0..servers_per_rack {
            let s = rack * servers_per_rack + srv;
            let sched = gen.schedule_for(&spec, s, &base_rng)?;
            let mut rng = base_rng.fork(s as u64);
            let tr = gen.server_trace(&art, &cls, &sched, horizon_s, dt, &mut rng)?;
            for (o, &p) in row.iter_mut().zip(&tr.power_w) {
                *o += p as f64 + 1000.0; // + non-GPU IT power
            }
        }
        let series: Vec<f32> = row.iter().map(|&x| (x / 1e3) as f32).collect();
        let p95 = percentile(&series, 95.0)?;
        if p95 <= limit_kw {
            max_ok = rack + 1;
        } else {
            println!("rack {:>2}: P95 = {p95:.0} kW — limit exceeded, stopping", rack + 1);
            break;
        }
        if (rack + 1) % 5 == 0 {
            println!("rack {:>2}: P95 = {p95:.0} kW", rack + 1);
        }
    }
    println!(
        "trace-based provisioning fits {max_ok} racks vs {nameplate_racks} by nameplate ({}x density)",
        max_ok as f64 / nameplate_racks.max(1) as f64
    );
    Ok(())
}
