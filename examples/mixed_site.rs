//! Mixed-class campus: an inference facility driven by the token-level
//! workload engine (sampled prompt/decode lengths packed under a batch
//! cap and a KV token budget) composed with a training facility archetype
//! (deterministic compute/checkpoint square wave) at one utility point of
//! interconnection. The planning story is the smoothing: the training
//! steps dominate the site's absolute ramps, but the inference class
//! raises the average load, so the *relative* ramp the utility must
//! follow shrinks.
//!
//!     cargo run --release --example mixed_site -- [horizon_h]
//!
//! Defaults: 4 h horizon, dt 1 s, 15 min lockstep windows, on a synthetic
//! random-weight artifact store, so it runs without `make artifacts`.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, WorkloadSpec};
use powertrace_sim::export::DirSink;
use powertrace_sim::site::{FacilitySpec, SiteSpec, TrainingSpec};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TokenLengths;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let horizon_h: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(4.0);

    let (mut gen, ids) = synth_generator("mixed_site", 16, 6, 1, 11)?;
    // Inference facility: token-level requests — lognormal prompt/decode
    // lengths, batches packed to 24 slots under a 16 k-token KV budget.
    let mut inference = ScenarioSpec::default_poisson(&ids[0], 0.5);
    inference.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 8 };
    inference.workload = WorkloadSpec::Token {
        rate: 0.6,
        lengths: TokenLengths::Lognormal {
            in_median: 512.0,
            in_sigma: 0.9,
            out_median: 128.0,
            out_sigma: 0.7,
        },
        max_batch: 24,
        token_budget: 16_384,
    };
    inference.horizon_s = horizon_h * 3600.0;
    inference.seed = 3;

    // Training facility: full power during compute, base power during
    // checkpoint stalls, phase-shifted half a period so the drops land
    // away from the inference facility's load.
    let training = TrainingSpec {
        horizon_s: inference.horizon_s,
        base_w: 15e3,
        amplitude_w: 60e3,
        period_s: 1800.0,
        duty: 0.8,
    };

    let spec = SiteSpec {
        name: "mixed_campus".into(),
        nameplate_w: Some(160e3),
        utility_intervals_s: vec![300.0, 900.0],
        facilities: vec![
            FacilitySpec::inference("serve0", 0.0, inference),
            FacilitySpec::training("train0", 900.0, training.clone()),
        ],
        overlays: Vec::new(),
    };

    let out_dir = std::env::temp_dir().join("powertrace_mixed_site");
    let req = RunRequest {
        spec: RunSpec::Site(spec.clone()),
        options: RunOptions::defaults_for(RunKind::Site).with_dt(1.0).with_window(900.0),
    };
    let sink = DirSink::new(&out_dir);
    let RunOutcome::Site(report) = api::execute(&mut gen, &req, Some(&sink))? else {
        unreachable!()
    };

    println!(
        "site '{}': token-workload inference ({} servers) + training archetype, {horizon_h} h\n",
        spec.name,
        spec.n_servers(),
    );
    print!("{}", report.summary_table());
    println!("\nwrote site_load.csv + site_summary.csv under {}", out_dir.display());

    // The training stream is deterministic: seedless, serverless, and
    // peaking exactly at base + amplitude.
    let train = &report.facilities[1];
    anyhow::ensure!(
        train.role == "training" && train.seed.is_none() && train.servers == 0,
        "training row must be seedless and serverless"
    );
    anyhow::ensure!(
        train.summary.stats.peak_w == training.base_w + training.amplitude_w,
        "training peak {} != step top {}",
        train.summary.stats.peak_w,
        training.base_w + training.amplitude_w
    );
    // Composition stays additive in energy across the two classes.
    let fac_energy: f64 = report.facilities.iter().map(|f| f.summary.stats.energy_kwh).sum();
    anyhow::ensure!(
        (report.site.stats.energy_kwh - fac_energy).abs() < 1e-6 * fac_energy,
        "site energy {} != sum of class energies {fac_energy}",
        report.site.stats.energy_kwh
    );
    anyhow::ensure!(
        report.coincidence_factor > 0.0 && report.coincidence_factor <= 1.0,
        "coincidence factor out of range"
    );
    Ok(())
}
