//! Utility-facing site interconnection study (paper title: "from servers
//! to sites"): three facilities in different timezones, each running the
//! same diurnal serving scenario phase-shifted by its longitude, composed
//! into the one load profile a utility actually plans against. The
//! composed shape — not any facility's — carries the planning answers:
//! the coincidence factor between facility peaks, the load-duration
//! curve, ramp rates at dispatch/settlement intervals, and headroom
//! against the interconnection nameplate.
//!
//!     cargo run --release --example site_interconnect -- [n_facilities] [stagger_h]
//!
//! Defaults: 3 facilities staggered 6 h apart, 24 h horizon, dt 1 s, 1 h
//! lockstep windows, on a synthetic random-weight artifact store
//! (`testutil::synth_generator`), so it runs without `make artifacts`.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, WorkloadSpec};
use powertrace_sim::export::DirSink;
use powertrace_sim::site::SiteSpec;
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TrafficMode;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_facilities: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let stagger_h: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6.0);

    let (mut gen, ids) = synth_generator("site_interconnect", 16, 6, 1, 11)?;
    let mut base = ScenarioSpec::default_poisson(&ids[0], 0.5);
    base.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 8 };
    base.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.35,
        mode: TrafficMode::SharedIntensity, // correlated demand within a facility
    };
    base.horizon_s = 24.0 * 3600.0;
    base.seed = 3;

    let mut spec = SiteSpec::staggered("tz_ladder", &base, n_facilities, stagger_h);
    // Interconnection nameplate: a deliberately generous per-facility
    // allowance, so the headroom row shows what diversity buys back.
    spec.nameplate_w = Some(n_facilities as f64 * 80e3);

    let out_dir = std::env::temp_dir().join("powertrace_site_interconnect");
    let req = RunRequest {
        spec: RunSpec::Site(spec.clone()),
        options: RunOptions::defaults_for(RunKind::Site).with_dt(1.0).with_window(3600.0),
    };
    let sink = DirSink::new(&out_dir);
    let RunOutcome::Site(report) = api::execute(&mut gen, &req, Some(&sink))? else {
        unreachable!()
    };

    println!(
        "site '{}': {} facilities staggered {stagger_h} h, {} servers, 24 h @ {}s\n",
        spec.name,
        n_facilities,
        spec.n_servers(),
        req.options.dt_s
    );
    print!("{}", report.summary_table());
    println!(
        "\nwrote site_load.csv + site_summary.csv under {} (the shareable artifacts —\n\
         raw serving telemetry never leaves any operator)",
        out_dir.display()
    );

    anyhow::ensure!(
        report.coincidence_factor > 0.0 && report.coincidence_factor <= 1.0,
        "coincidence factor out of range"
    );
    anyhow::ensure!(
        report.site.stats.peak_w <= report.sum_facility_peaks_w * (1.0 + 1e-6),
        "site peak exceeds the non-coincident sum"
    );
    Ok(())
}
