//! Facility planning: run a small data hall under a diurnal Azure-like
//! workload and extract the interconnection-facing quantities of the
//! paper's Table 3 (peak, average, PAR, ramp, load factor).
//!
//!     cargo run --release --example facility_planning

use powertrace_sim::aggregate::{resample, Topology};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::metrics::PlanningStats;
use powertrace_sim::workload::TrafficMode;

fn main() -> anyhow::Result<()> {
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(_) => Generator::native()?,
    };

    // A 2-row × 3-rack × 4-server hall (24 servers) for a quick run;
    // scale `topology` up to the paper's 10×6×4 = 240 servers.
    let mut spec = ScenarioSpec::default_poisson("llama70b_a100_tp8", 0.5);
    spec.topology = Topology { rows: 2, racks_per_row: 3, servers_per_rack: 4 };
    spec.server_config = ServerAssignment::Uniform("llama70b_a100_tp8".into());
    spec.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.35,
        mode: TrafficMode::Independent,
    };
    spec.horizon_s = 6.0 * 3600.0; // 6 hours
    spec.pue = 1.3;
    spec.seed = 42;

    let dt = 1.0;
    let t0 = std::time::Instant::now();
    let run = gen.facility(&spec, dt, 0)?;
    let site = run.facility_series();
    println!(
        "generated {} servers × {:.0} h in {:.1} s",
        spec.topology.n_servers(),
        spec.horizon_s / 3600.0,
        t0.elapsed().as_secs_f64()
    );

    let stats = PlanningStats::compute(&site, dt, 900.0)?;
    let nameplate_mw = gen.cat.server_nameplate_w(gen.cat.config("llama70b_a100_tp8")?)
        * spec.topology.n_servers() as f64
        * spec.pue
        / 1e6;
    println!("-- interconnection view (PCC, PUE {}) --", spec.pue);
    println!("  nameplate (TDP)     : {nameplate_mw:.3} MW");
    println!("  peak facility power : {:.3} MW", stats.peak_w / 1e6);
    println!("  average power       : {:.3} MW", stats.avg_w / 1e6);
    println!("  peak-to-average     : {:.2}", stats.peak_to_average);
    println!("  max 15-min ramp     : {:.3} MW", stats.max_ramp_w / 1e6);
    println!("  load factor         : {:.2}", stats.load_factor);
    println!(
        "  nameplate overstates the interconnection need by {:.0}%",
        (nameplate_mw * 1e6 / stats.peak_w - 1.0) * 100.0
    );

    // 15-minute load shape a utility would consume.
    let shape = resample(&site, dt, 900.0)?;
    println!("-- 15-min load shape (MW) --");
    for (i, p) in shape.iter().enumerate() {
        println!("  t+{:>3} min: {:.3}", i * 15, p / 1e6);
    }
    Ok(())
}
