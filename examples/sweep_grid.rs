//! Scenario sweep: run a whole family of serving scenarios — steady vs
//! bursty traffic × homogeneous vs mixed fleets × seeds — in one call,
//! with per-configuration artifacts shared across every cell, and compare
//! the planning envelope across the grid (paper §5: "new traffic
//! conditions and serving configurations").
//!
//!     cargo run --release --example sweep_grid
//!
//! Requires `make artifacts`. Writes the grid + multi-scale series under
//! `out/sweep_grid/`.

use powertrace_sim::api::{self, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::scenarios::SweepGrid;

fn main() -> anyhow::Result<()> {
    let mut gen = match Generator::pjrt() {
        Ok(g) => g,
        Err(e) => {
            eprintln!("pjrt unavailable ({e:#}); using native backend");
            Generator::native()?
        }
    };

    // The built-in demo grid: 2 workloads × 1 topology × 2 fleets × 2 seeds
    // = 8 scenarios. Write it out so the same sweep can be re-run (and
    // re-produced bit-identically) from the CLI:
    //   powertrace sweep --grid out/sweep_grid/grid.json
    let ids = gen.store.manifest.configs.clone();
    let grid = SweepGrid::example("sweep_grid", &ids, 600.0);
    println!(
        "grid '{}': {} cells over {} unique configs\n",
        grid.name,
        grid.n_cells(),
        grid.config_ids().len()
    );

    let req = RunRequest::new(RunSpec::Sweep(grid.clone()));
    let RunOutcome::Sweep(report) = api::execute(&mut gen, &req, None)? else { unreachable!() };
    print!("{}", report.summary_table());

    // The multi-scale export: every cell carries rack-level 1 s, row-level
    // 15 s, and facility-level 5/15 min series from one streaming pass.
    let first = &report.cells[0];
    let scales = first.scales.as_ref().expect("buffered cells carry scales");
    println!(
        "\ncell {}: {} racks @1s ({} pts), {} rows @15s ({} pts), facility @300s ({} pts)",
        first.cell.id,
        scales.racks_w.len(),
        scales.racks_w[0].len(),
        scales.rows_w.len(),
        scales.rows_w[0].len(),
        scales.facility_w[0].len(),
    );

    let out = std::path::Path::new("out/sweep_grid");
    report.write(out)?;
    println!("wrote {} cells + summary.csv under {}", report.cells.len(), out.display());
    Ok(())
}
