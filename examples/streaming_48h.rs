//! Long-horizon streaming smoke: a 240-server synthetic fleet generated
//! over a 48 h horizon through the windowed engine — the scenario that is
//! simply impossible for the buffered path on CI-class memory (racks × T
//! plus per-lane full-horizon feature/state buffers run to multiple GB).
//! Streaming memory is O(racks × window) samples plus the compressed
//! workload event lists; CI runs this binary under `/usr/bin/time -v` and
//! asserts the peak RSS stays bounded.
//!
//!     cargo run --release --example streaming_48h -- [horizon_h] [window_s]
//!
//! Defaults: 48 h horizon, 1 h windows, dt 250 ms, 6×5×8 = 240 servers on
//! a synthetic random-weight artifact store (`testutil::synth_generator`),
//! so it runs without `make artifacts`.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::ScenarioSpec;
use powertrace_sim::metrics::planning::StreamingPlanningStats;
use powertrace_sim::testutil::synth_generator;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let horizon_h: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(48.0);
    let window_s: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3600.0);
    let dt = 0.25;

    let (mut gen, ids) = synth_generator("streaming_48h", 8, 4, 1, 7)?;
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 0.1);
    spec.topology = Topology { rows: 6, racks_per_row: 5, servers_per_rack: 8 }; // 240 servers
    spec.horizon_s = horizon_h * 3600.0;
    spec.seed = 1;

    let n_steps = (spec.horizon_s / dt).round() as usize;
    println!(
        "streaming {} servers × {horizon_h} h @ {dt}s ({n_steps} steps) in {window_s}s windows",
        spec.topology.n_servers()
    );
    // Cap retained samples at 512 Ki — below the 48 h default's 691,200
    // site samples — so the smoke actually exercises the
    // histogram-quantile path (the thing the bound documents) while
    // peak/mean/energy/ramp stay exact folds.
    let mut stats = StreamingPlanningStats::with_exact_cap(dt, 900.0, 1 << 19)?;
    let mut rows = Vec::new();
    let mut site = Vec::new();
    let mut pcc = Vec::new();
    let mut n_windows = 0usize;
    let pue = spec.pue;
    let t0 = std::time::Instant::now();
    gen.facility_windowed(&spec, dt, window_s, 0, 0, |acc| {
        acc.fold_rows_site(&mut rows, &mut site);
        powertrace_sim::aggregate::pcc_window_into(&site, pue, &mut pcc);
        stats.push_slice(&pcc);
        n_windows += 1;
        if n_windows % 8 == 0 {
            println!(
                "  window {n_windows}: t = {:.1} h ({:.0}s wall)",
                (acc.window_t0() + acc.window_len()) as f64 * dt / 3600.0,
                t0.elapsed().as_secs_f64()
            );
        }
        Ok(())
    })?;
    let out = stats.finalize()?;
    println!(
        "done in {:.1}s: {n_windows} windows → peak {:.3} MW, avg {:.3} MW, p99 {:.3} MW{}, \
         energy {:.1} MWh, 15-min ramp {:.3} MW",
        t0.elapsed().as_secs_f64(),
        out.stats.peak_w / 1e6,
        out.stats.avg_w / 1e6,
        out.stats.p99_w / 1e6,
        if out.exact_quantiles {
            String::new()
        } else {
            format!(" (±{:.1} W hist)", out.p99_error_bound_w)
        },
        out.stats.energy_kwh / 1e3,
        out.stats.max_ramp_w / 1e6,
    );
    anyhow::ensure!(out.stats.peak_w > 0.0 && out.stats.energy_kwh > 0.0, "degenerate output");
    Ok(())
}
